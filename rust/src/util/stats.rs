//! Descriptive statistics used by the evaluation + bench harnesses:
//! mean/std/standard-error, geometric mean (Table 2), percentiles, and a
//! streaming Welford accumulator.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn std_err(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Geometric mean (the paper's Table 2 aggregate). Non-positive inputs are
/// clamped to a tiny epsilon so a single degenerate run cannot zero the
/// aggregate.
pub fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Percentile via linear interpolation on the sorted sample. `q` in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Min and max of a slice (0.0s for empty input).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    (lo, hi)
}

/// Streaming mean/variance (Welford). Used by coordinator telemetry so the
/// service never stores per-request samples.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Parallel combine (Chan et al.): after merging, this accumulator is
    /// exactly what it would have been had it seen `other`'s samples too.
    /// Used by the scheduler to fold telemetry-derived moments into live
    /// per-(tenant, op-class, bucket) estimators without replaying samples.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let delta = other.mean - self.mean;
        self.mean += delta * nb / (na + nb);
        self.m2 += other.m2 + delta * delta * na * nb / (na + nb);
        self.n += other.n;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Reconstruct an accumulator from summary moments (sample variance,
    /// n-1 denominator). The inverse of (`n`, `mean()`, `var()`, `min()`,
    /// `max()`) — lets cross-process artifacts (histogram-derived moments)
    /// seed a live estimator.
    pub fn from_moments(n: u64, mean: f64, var: f64, min: f64, max: f64) -> Welford {
        if n == 0 {
            return Welford::new();
        }
        Welford {
            n,
            mean,
            m2: if n < 2 { 0.0 } else { var * (n - 1) as f64 },
            min,
            max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert!((std_err(&xs) - 0.6454972).abs() < 1e-6);
    }

    #[test]
    fn geo_mean_matches_paper_style() {
        // geomean of {10, 1000} = 100
        assert!((geo_mean(&[10.0, 1000.0]) - 100.0).abs() < 1e-9);
        assert_eq!(geo_mean(&[]), 0.0);
        // degenerate zero clamps instead of nuking the aggregate
        assert!(geo_mean(&[0.0, 100.0]) > 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 7.0, 7.0, 19.0, 24.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 3.0);
        assert_eq!(w.max(), 24.0);
    }

    #[test]
    fn welford_merge_matches_push_all() {
        let xs = [3.0, 7.0, 7.0, 19.0, 24.0, -2.0, 0.5];
        for split in 0..=xs.len() {
            let mut a = Welford::new();
            let mut b = Welford::new();
            for &x in &xs[..split] {
                a.push(x);
            }
            for &x in &xs[split..] {
                b.push(x);
            }
            let mut all = Welford::new();
            for &x in &xs {
                all.push(x);
            }
            a.merge(&b);
            assert_eq!(a.n, all.n, "split {split}");
            assert!((a.mean() - all.mean()).abs() < 1e-12, "split {split}");
            assert!((a.var() - all.var()).abs() < 1e-10, "split {split}");
            assert_eq!(a.min(), all.min(), "split {split}");
            assert_eq!(a.max(), all.max(), "split {split}");
        }
    }

    #[test]
    fn welford_from_moments_roundtrip() {
        let mut w = Welford::new();
        for x in [0.01, 0.02, 0.05, 0.03] {
            w.push(x);
        }
        let r = Welford::from_moments(w.n, w.mean(), w.var(), w.min(), w.max());
        assert_eq!(r.n, w.n);
        assert!((r.mean() - w.mean()).abs() < 1e-15);
        assert!((r.var() - w.var()).abs() < 1e-15);
        assert_eq!(r.min(), w.min());
        assert_eq!(r.max(), w.max());
        // Empty and single-sample edges.
        assert_eq!(Welford::from_moments(0, 5.0, 1.0, 0.0, 9.0).mean(), 0.0);
        let one = Welford::from_moments(1, 0.5, 0.0, 0.5, 0.5);
        assert_eq!(one.mean(), 0.5);
        assert_eq!(one.var(), 0.0);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(min_max(&[]), (0.0, 0.0));
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.std(), 0.0);
    }
}
