//! `dare` — CLI for the DaRE-forest unlearning system.
//!
//! Subcommands:
//!   train      train a forest on a corpus dataset or CSV, optionally save
//!   delete     unlearn instances from a saved model
//!   predict    score a CSV with a saved model
//!   serve      run the unlearning service (JSON-lines over TCP); with
//!              --follow it runs as a read-only WAL-tailing follower
//!   promote    flip a follower model into a writable leader (failover)
//!   tune       run the paper's hyperparameter tuning protocol
//!   reproduce  regenerate a paper table/figure (fig1 fig2 fig3 table2
//!              table3 table5 table6 table7 table9 | all)
//!   datasets   list the 14-dataset corpus

use dare::coordinator::{
    bootstrap_follower, serve, Client, ReplicationConfig, Scheduler, SchedulerConfig,
    ServiceConfig, UnlearningService,
};
use dare::data::registry::{corpus, find};
use dare::data::split::train_test;
use dare::eval::tuner::Grid;
use dare::exp;
use dare::forest::{serialize, DareForest, Params, SplitCriterion};
use dare::metrics::Metric;
use dare::util::cli::{parse, Args};
use dare::util::table::Table;
use std::path::Path;

const VALUE_KEYS: &[&str] = &[
    "dataset", "scale", "trees", "depth", "k", "drmax", "criterion", "seed", "threads", "save",
    "load", "csv", "ids", "addr", "workers", "repeats", "deletions", "worst-of", "datasets",
    "out-dir", "max-trees", "ks", "grid", "folds", "tolerances", "label", "n", "model",
    "wal-dir", "fsync", "snapshot-every", "hmac-key", "follow", "poll-ms", "pull-batch",
    "stale-after", "retries", "connect-timeout-ms", "io-timeout-ms", "budget-ms", "queue-depth",
    "fairness",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse(argv, VALUE_KEYS);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "train" => cmd_train(&args),
        "delete" => cmd_delete(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "promote" => cmd_promote(&args),
        "tune" => cmd_tune(&args),
        "reproduce" => cmd_reproduce(&args),
        "datasets" => cmd_datasets(),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "dare — machine unlearning for random forests (Brophy & Lowd, ICML 2021)

USAGE: dare <command> [flags]

COMMANDS
  train      --dataset <name>|--csv <file> [--scale N] [--trees T] [--depth D]
             [--k K] [--drmax R] [--criterion gini|entropy] [--save model.json]
  delete     --load model.json --ids 1,2,3 [--save out.json]
  predict    --load model.json --csv data.csv
  serve      --load model.json|--dataset <name> [--addr 127.0.0.1:7878]
             [--workers W] [--model NAME]   (NAME defaults to 'default';
             further models can be created/loaded over the wire)
             durability: [--wal-dir DIR] [--fsync every_op|every:<n>|interval_ms:<ms>]
             [--snapshot-every N] [--hmac-key KEY]  (write-ahead log +
             crash recovery + signed deletion certificates; with --wal-dir,
             journaled state wins over --load for already-served names)
             replication: --follow LEADER_ADDR runs a read-only follower
             that bootstraps from the leader's snapshot and tails its WAL
             [--poll-ms MS] [--pull-batch N] [--stale-after EPOCHS]
             [--retries R] [--connect-timeout-ms MS] [--io-timeout-ms MS]
             scheduling: --budget-ms MS serves through the deadline-aware
             cross-tenant scheduler (MS latency budget per cycle; requests
             may carry \"deadline_ms\") [--queue-depth N]  (per-tenant
             admission bound, refused ops answer overloaded+retry_after_ms)
             [--fairness tenant=weight,...]  (deficit-round-robin shares)
  promote    --addr <follower> [--model NAME]  flip a follower model into
             a writable leader (drains catch-up first; failover)
  tune       --dataset <name> [--scale N] [--grid paper|small] [--folds F]
  reproduce  <fig1|fig2|fig3|table2|table3|table5|table6|table7|table9|all>
             [--scale N] [--repeats R] [--deletions D] [--worst-of C]
             [--datasets a,b] [--criterion gini|entropy] [--max-trees T]
             [--out-dir results]
  datasets   list the corpus (paper Table 1)"
    );
}

fn load_params(args: &Args, defaults: Params) -> anyhow::Result<Params> {
    let criterion: SplitCriterion = args
        .get_or("criterion", "gini")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    Ok(Params {
        n_trees: args.usize("trees", defaults.n_trees),
        max_depth: args.usize("depth", defaults.max_depth),
        k: args.usize("k", defaults.k),
        d_rmax: args.usize("drmax", defaults.d_rmax),
        criterion,
        n_threads: args.usize("threads", dare::util::threadpool::default_threads()),
        ..defaults
    })
}

fn load_training_data(args: &Args) -> anyhow::Result<(dare::data::Dataset, Params, Metric)> {
    if let Some(csv) = args.get("csv") {
        let data = dare::data::io::load_csv(Path::new(csv))?;
        let params = load_params(args, Params::default())?;
        return Ok((data, params, Metric::Accuracy));
    }
    let name = args
        .get("dataset")
        .ok_or_else(|| anyhow::anyhow!("--dataset or --csv required"))?;
    let info = find(name).ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
    let scale = args.usize("scale", 500);
    let data = info.generate(scale, args.u64("seed", 1));
    let defaults = Params::from_paper(&info.gini, 0);
    let params = load_params(args, defaults)?;
    Ok((data, params, info.metric))
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let (data, params, metric) = load_training_data(args)?;
    let (train, test) = train_test(&data, 0.8, args.u64("seed", 1));
    let (_, test_ys, _) = test.to_row_major();
    println!(
        "training DaRE forest: n={} p={} T={} d_max={} k={} d_rmax={} criterion={:?}",
        train.n_total(),
        train.n_features(),
        params.n_trees,
        params.max_depth,
        params.k,
        params.d_rmax,
        params.criterion
    );
    let (forest, secs) =
        dare::util::timer::time(|| DareForest::fit(train, &params, args.u64("seed", 1)));
    let probs = forest.predict_proba_dataset(&test);
    println!(
        "trained in {:.2}s; test {} = {:.4}",
        secs,
        metric.name(),
        metric.score(&probs, &test_ys)
    );
    let mem = forest.memory();
    println!(
        "memory: structure={}KB decision_stats={}KB leaf_stats={}KB",
        mem.structure / 1024,
        mem.decision_stats / 1024,
        mem.leaf_stats / 1024
    );
    if let Some(path) = args.get("save") {
        serialize::save(&forest, Path::new(path))?;
        println!("saved model to {path}");
    }
    Ok(())
}

fn cmd_delete(args: &Args) -> anyhow::Result<()> {
    let path = args
        .get("load")
        .ok_or_else(|| anyhow::anyhow!("--load <model.json> required"))?;
    let mut forest = serialize::load(Path::new(path))?;
    let ids: Vec<u32> = args
        .get("ids")
        .ok_or_else(|| anyhow::anyhow!("--ids 1,2,3 required"))?
        .split(',')
        .map(|s| s.trim().parse::<u32>())
        .collect::<Result<_, _>>()?;
    let ((report, skipped), secs) = dare::util::timer::time(|| forest.delete_batch(&ids));
    println!(
        "deleted {} instances ({} skipped) in {:.4}s; retrain cost = {} instances across {} events",
        ids.len() - skipped,
        skipped,
        secs,
        report.cost(),
        report.retrain_events()
    );
    let out = args.get("save").unwrap_or(path);
    serialize::save(&forest, Path::new(out))?;
    println!("saved updated model to {out}");
    Ok(())
}

fn cmd_predict(args: &Args) -> anyhow::Result<()> {
    let path = args
        .get("load")
        .ok_or_else(|| anyhow::anyhow!("--load <model.json> required"))?;
    let forest = serialize::load(Path::new(path))?;
    let csv = args
        .get("csv")
        .ok_or_else(|| anyhow::anyhow!("--csv <file> required"))?;
    let data = dare::data::io::load_csv(Path::new(csv))?;
    let probs = forest.predict_proba_dataset(&data);
    let (_, ys, _) = data.to_row_major();
    for (i, p) in probs.iter().enumerate() {
        println!("{i},{p:.6}");
    }
    eprintln!(
        "accuracy={:.4} auc={:.4}",
        dare::metrics::accuracy(&probs, &ys),
        dare::metrics::auc(&probs, &ys)
    );
    Ok(())
}

/// Build + attach the cross-tenant scheduler (DESIGN.md §15) when
/// `--budget-ms` asks for scheduled serving. The returned `Arc` must stay
/// alive across `serve` — the service only holds it weakly.
fn scheduler_from_flags(
    args: &Args,
    svc: &std::sync::Arc<UnlearningService>,
) -> anyhow::Result<Option<std::sync::Arc<Scheduler>>> {
    let Some(budget) = args.get("budget-ms") else {
        anyhow::ensure!(
            args.get("queue-depth").is_none() && args.get("fairness").is_none(),
            "--queue-depth/--fairness require --budget-ms (scheduled serving)"
        );
        return Ok(None);
    };
    let budget_ms: u64 = budget
        .parse()
        .ok()
        .filter(|&ms| ms > 0)
        .ok_or_else(|| anyhow::anyhow!("--budget-ms: expected milliseconds > 0, got '{budget}'"))?;
    let mut cfg = SchedulerConfig::default();
    cfg.budget = std::time::Duration::from_millis(budget_ms);
    cfg.queue_depth = args.usize("queue-depth", cfg.queue_depth);
    if let Some(spec) = args.get("fairness") {
        cfg.weights =
            SchedulerConfig::parse_weights(spec).map_err(|e| anyhow::anyhow!("--fairness: {e}"))?;
    }
    println!(
        "scheduler: {budget_ms}ms budget cycles, queue depth {}, {} fairness weight(s)",
        cfg.queue_depth,
        cfg.weights.len()
    );
    let sched = Scheduler::attach(svc, cfg);
    Scheduler::spawn_runner(&sched);
    Ok(Some(sched))
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut cfg = ServiceConfig::default();
    if let Some(dir) = args.get("wal-dir") {
        cfg.wal_dir = Some(dir.into());
    }
    if let Some(policy) = args.get("fsync") {
        cfg.wal_fsync = dare::coordinator::FsyncPolicy::parse(policy).ok_or_else(|| {
            anyhow::anyhow!("--fsync: expected every_op | every:<n> | interval_ms:<ms>, got '{policy}'")
        })?;
    }
    cfg.wal_snapshot_every = args.u64("snapshot-every", cfg.wal_snapshot_every);
    cfg.cert_key = args.get("hmac-key").map(str::to_string);
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let workers = args.usize("workers", 4);

    // Follower mode (DESIGN.md §12): no local training — every served model
    // bootstraps from the leader's snapshot and then tails its WAL.
    if let Some(leader) = args.get("follow") {
        let durable = cfg.wal_dir.is_some();
        let mut rcfg = ReplicationConfig {
            leader: leader.to_string(),
            ..Default::default()
        };
        rcfg.poll_interval = args.duration_ms("poll-ms", rcfg.poll_interval);
        rcfg.max_records = args.usize("pull-batch", rcfg.max_records);
        rcfg.stale_after_epochs = args.u64("stale-after", rcfg.stale_after_epochs);
        rcfg.client.retries = args.u64("retries", u64::from(rcfg.client.retries)) as u32;
        rcfg.client.connect_timeout =
            args.duration_ms("connect-timeout-ms", rcfg.client.connect_timeout);
        rcfg.client.io_timeout = args.duration_ms("io-timeout-ms", rcfg.client.io_timeout);
        let svc = UnlearningService::with_models(Vec::new(), cfg);
        let _sched = scheduler_from_flags(args, &svc)?;
        let followed = bootstrap_follower(&svc, &rcfg)?;
        anyhow::ensure!(
            !followed.is_empty(),
            "leader {leader} serves no models to follow"
        );
        println!(
            "dare read-only follower (wire v{}, leader {leader}, models [{}], durable={durable})",
            dare::coordinator::WIRE_VERSION,
            followed.join(", ")
        );
        return serve(svc, addr, workers, |bound| {
            println!(
                "listening on {bound} (JSON-lines; read-only follower — \
                 mutations answer read_only; send {{\"op\":\"promote\"}} to fail over)"
            );
        });
    }

    let name = args.get_or("model", dare::coordinator::DEFAULT_MODEL);
    // With a WAL dir, durable on-disk state wins over --load/--dataset for
    // any model name it already covers (DESIGN.md §11) — the flags only
    // seed models that have no journal yet.
    let forest = if let Some(path) = args.get("load") {
        serialize::load(Path::new(path))?
    } else {
        let (data, params, _) = load_training_data(args)?;
        println!("no --load given; training a fresh model first...");
        DareForest::fit(data, &params, args.u64("seed", 1))
    };
    let durable = cfg.wal_dir.is_some();
    let svc = UnlearningService::with_models(vec![(name.to_string(), forest)], cfg);
    let _sched = scheduler_from_flags(args, &svc)?;
    println!(
        "dare unlearning service (wire v{}, model '{name}', pjrt={}, durable={durable})",
        dare::coordinator::WIRE_VERSION,
        svc.registry().get(name).map(|m| m.pjrt_active()).unwrap_or(false)
    );
    serve(svc, addr, workers, |bound| {
        println!(
            "listening on {bound} (JSON-lines; v1 requests carry \
             {{\"v\":1,\"model\":...}}; send {{\"op\":\"shutdown\"}} to stop)"
        );
    })
}

fn cmd_promote(args: &Args) -> anyhow::Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("--addr <follower addr> required"))?;
    let model = args.get_or("model", dare::coordinator::DEFAULT_MODEL);
    let mut client = Client::connect(addr)?;
    let epoch = client.promote(model)?;
    println!("promoted '{model}' on {addr}: now a writable leader at wal epoch {epoch}");
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let grid = match args.get_or("grid", "small") {
        "paper" => Grid::paper(),
        _ => Grid::small(),
    };
    let r = exp::table6::run(&cfg, &grid, args.usize("folds", 5))?;
    println!("{}", exp::table6::render(&r, cfg.criterion_tag()));
    Ok(())
}

fn exp_config(args: &Args) -> anyhow::Result<exp::ExpConfig> {
    let criterion: SplitCriterion = args
        .get_or("criterion", "gini")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    Ok(exp::ExpConfig {
        scale_div: args.usize("scale", 500),
        repeats: args.usize("repeats", 1),
        max_deletions: args.usize("deletions", 150),
        worst_of: args.usize("worst-of", 100),
        datasets: args.str_list("datasets").unwrap_or_default(),
        criterion,
        threads: args.usize("threads", dare::util::threadpool::default_threads()),
        max_trees: args.usize("max-trees", 0),
        seed: args.u64("seed", 1),
        out_dir: args.get_or("out-dir", "results").into(),
    })
}

fn cmd_reproduce(args: &Args) -> anyhow::Result<()> {
    let what = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "reproduce what? (fig1|fig2|fig3|table2|table3|table5|table6|table7|table9|all)"
            )
        })?;
    let mut cfg = exp_config(args)?;
    let run_one = |what: &str, cfg: &exp::ExpConfig| -> anyhow::Result<()> {
        match what {
            "fig1" => {
                let r = exp::fig1::run(cfg)?;
                println!("{}", exp::fig1::render(&r));
            }
            "table2" => {
                let rows = exp::table2::run(cfg)?;
                println!("{}", exp::table2::render(&rows, cfg.criterion_tag()));
            }
            "table9" => {
                let mut c = cfg.clone();
                c.criterion = SplitCriterion::Entropy;
                let rows = exp::table2::run(&c)?;
                println!("{}", exp::table2::render(&rows, "entropy"));
            }
            "fig2" => {
                let ds = args.get_or("dataset", "bank_marketing");
                let r = exp::fig2::run(cfg, ds)?;
                println!("{}", exp::fig2::render(&r));
            }
            "fig3" => {
                let ds = args.get_or("dataset", "surgical");
                let ks = args.usize_list("ks", &[1, 5, 10, 25, 50, 100]);
                let r = exp::fig3::run(cfg, ds, &ks)?;
                println!("{}", exp::fig3::render(&r));
            }
            "table3" => {
                let r = exp::table3::run(cfg)?;
                println!("{}", exp::table3::render(&r));
            }
            "table5" => {
                let r = exp::table5::run(cfg)?;
                println!("{}", exp::table5::render(&r));
            }
            "table6" => {
                let grid = match args.get_or("grid", "small") {
                    "paper" => Grid::paper(),
                    _ => Grid::small(),
                };
                let r = exp::table6::run(cfg, &grid, args.usize("folds", 5))?;
                println!("{}", exp::table6::render(&r, cfg.criterion_tag()));
            }
            "table7" => {
                let r = exp::table7::run(cfg)?;
                println!("{}", exp::table7::render(&r));
            }
            other => anyhow::bail!("unknown experiment '{other}'"),
        }
        Ok(())
    };
    if what == "all" {
        for w in [
            "fig1", "table2", "fig2", "fig3", "table3", "table5", "table6", "table7", "table9",
        ] {
            println!("\n##### reproduce {w} #####");
            run_one(w, &cfg)?;
        }
    } else {
        if what == "table9" {
            cfg.criterion = SplitCriterion::Entropy;
        }
        run_one(what, &cfg)?;
    }
    Ok(())
}

fn cmd_datasets() -> anyhow::Result<()> {
    let mut t = Table::new(
        "DaRE corpus (paper Table 1; synthetic generators, see DESIGN.md §2)",
        &["dataset", "n (paper)", "p", "pos %", "metric", "T", "d_max", "k", "drmax@tols"],
    );
    for d in corpus() {
        t.row(vec![
            d.name.to_string(),
            d.n_paper.to_string(),
            d.p.to_string(),
            format!("{:.1}", d.pos_pct),
            d.metric.name().to_string(),
            d.gini.n_trees.to_string(),
            d.gini.max_depth.to_string(),
            d.gini.k.to_string(),
            format!("{:?}", d.gini.drmax),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
