//! ISSUE 4: structural property tests over churn — for any seeded op
//! sequence under any deferral policy, every tree must satisfy:
//!
//! - **Count soundness**: each arena node's `n`/`n_pos` equals the size /
//!   positive-label sum over the leaf id lists below it
//!   (`ArenaTree::validate_counts`: leaf-level label sums, plus the
//!   parent-child sum checks of `validate`).
//! - **Leak-freedom**: live slots + free-list slots partition the arena
//!   exactly (no slot leaked, none reachable twice).
//! - **Dirty-set soundness**: every deferred-retrain entry names a live,
//!   leaf-shaped, flushable node (`DareTree::validate`), and the backlog
//!   arithmetic (`dirty == deferred - flushed`) holds.
//! - **Coverage**: the union of each tree's leaves is exactly the live
//!   instance set — deferral must never lose or duplicate an instance.

use dare::data::dataset::Dataset;
use dare::forest::{DareForest, LazyPolicy, MaxFeatures, Params};
use dare::util::prop::{gen_feature_column, gen_labels};
use dare::util::rng::{mix_seed, Rng};

fn random_dataset(rng: &mut Rng, n: usize, p: usize) -> Dataset {
    let cols: Vec<Vec<f32>> = (0..p)
        .map(|_| gen_feature_column(rng, n, 0.25, 3.5))
        .collect();
    let labels = gen_labels(rng, n, 0.3 + 0.4 * rng.f64());
    Dataset::from_columns(cols, labels)
}

fn check_forest(f: &DareForest, when: &str) {
    let mut live = f.data().live_ids();
    live.sort_unstable();
    for (t, tree) in f.trees().iter().enumerate() {
        // arena + dirty-set audit
        tree.validate()
            .unwrap_or_else(|e| panic!("{when}: tree {t} invalid: {e}"));
        // leaf-level label sums against the dataset
        tree.arena
            .validate_counts(f.data())
            .unwrap_or_else(|e| panic!("{when}: tree {t} count audit failed: {e}"));
        // root count == live instances
        assert_eq!(
            tree.n() as usize,
            f.n_alive(),
            "{when}: tree {t} root count != live instances"
        );
        // leaf union == live set (order-insensitive)
        let mut ids = Vec::with_capacity(live.len());
        tree.arena.collect_ids(tree.arena.root(), None, &mut ids);
        ids.sort_unstable();
        assert_eq!(ids, live, "{when}: tree {t} lost or duplicated instances");
        // backlog arithmetic
        assert_eq!(
            tree.dirty_len() as u64,
            tree.deferred_retrains() - tree.flushed_retrains(),
            "{when}: tree {t} backlog != deferred - flushed"
        );
    }
}

fn churn_case(seed: u64, policy: LazyPolicy) {
    let mut rng = Rng::new(mix_seed(&[seed, 0x57A7_5]));
    let n = 120 + rng.index(80);
    let p = 4 + rng.index(3);
    let data = random_dataset(&mut rng, n, p);
    let params = Params {
        n_trees: 3,
        max_depth: 7,
        k: 4,
        d_rmax: rng.index(3),
        max_features: MaxFeatures::Sqrt,
        ..Default::default()
    };
    let mut f = DareForest::fit(data, &params, rng.next_u64());
    f.set_lazy_policy(policy);
    check_forest(&f, "fresh");

    for op in 0..45 {
        match rng.index(10) {
            0..=5 if f.n_alive() > 25 => {
                let live = f.live_ids();
                let id = live[rng.index(live.len())];
                f.delete_seq(id).unwrap();
            }
            6..=7 | 0..=5 => {
                let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-4.0, 4.0)).collect();
                f.add(&row, rng.bernoulli(0.5) as u8);
            }
            8 => {
                // reads flush lazily — invariants must survive the mix
                let live = f.live_ids();
                let rows: Vec<Vec<f32>> = live
                    .iter()
                    .take(5)
                    .map(|&i| f.data().row(i))
                    .collect();
                f.predict_proba_rows_flushed(&rows);
            }
            _ => {
                f.compact(1);
            }
        }
        if op % 9 == 0 {
            check_forest(&f, &format!("seed {seed} {policy:?} op {op}"));
        }
    }
    check_forest(&f, &format!("seed {seed} {policy:?} end"));
    f.flush_all();
    check_forest(&f, &format!("seed {seed} {policy:?} flushed"));
    assert_eq!(f.dirty_subtrees(), 0);
}

#[test]
fn invariants_hold_under_churn_for_every_policy() {
    for seed in [1u64, 2, 3, 4] {
        for policy in [
            LazyPolicy::Eager,
            LazyPolicy::OnRead,
            LazyPolicy::Budgeted(2),
        ] {
            churn_case(seed, policy);
        }
    }
}

/// Deleting everything down to (near) nothing and flushing must leave
/// minimal, leak-free, fully-consistent trees.
#[test]
fn drain_to_empty_stays_consistent() {
    let mut rng = Rng::new(77);
    let data = random_dataset(&mut rng, 80, 4);
    let params = Params {
        n_trees: 2,
        max_depth: 6,
        k: 3,
        ..Default::default()
    };
    let mut f = DareForest::fit(data, &params, 5);
    f.set_lazy_policy(LazyPolicy::OnRead);
    while f.n_alive() > 1 {
        let live = f.live_ids();
        f.delete_seq(live[0]).unwrap();
    }
    check_forest(&f, "drained");
    f.flush_all();
    check_forest(&f, "drained+flushed");
    for tree in f.trees() {
        assert_eq!(tree.n(), 1);
    }
}
