//! ISSUE 3: golden snapshot fixtures — small serialized forests (fresh and
//! post-churn) checked in under `tests/fixtures/`, deserialized and
//! structurally compared on every run so serialization drift (or an RNG /
//! split-decision regression that changes what deterministic recipes build)
//! is caught without rebuilding old binaries. See `tests/fixtures/README.md`
//! for the bootstrap protocol (first cargo-capable run writes the files).

use dare::data::synth::{generate, SynthSpec};
use dare::forest::{serialize, DareForest, Params};
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// Deterministic recipe: fixed synth data, params, forest seed.
fn build_fresh() -> DareForest {
    let data = generate(
        &SynthSpec {
            n: 160,
            informative: 3,
            redundant: 1,
            noise: 2,
            flip: 0.05,
            ..Default::default()
        },
        42,
    );
    DareForest::fit(
        data,
        &Params {
            n_trees: 3,
            max_depth: 5,
            k: 5,
            d_rmax: 1,
            ..Default::default()
        },
        7,
    )
}

/// Fixed churn on top of the fresh recipe: deletions leave non-compact
/// arenas with live free lists, additions exercise the §6 path — the
/// snapshot schema has to carry all of it.
fn build_churned() -> DareForest {
    let mut f = build_fresh();
    let p = f.data().n_features();
    for id in [3u32, 17, 29, 41, 55, 80, 81] {
        f.delete_seq(id).unwrap();
    }
    for i in 0..5u32 {
        let row: Vec<f32> = (0..p).map(|j| 0.2 * i as f32 - 0.1 * j as f32).collect();
        f.add(&row, (i % 2) as u8);
    }
    f
}

fn check_golden(name: &str, rebuilt: DareForest) {
    let path = fixture_path(name);
    let fresh_json = serialize::forest_to_json(&rebuilt);
    if !path.exists() || std::env::var("DARE_UPDATE_FIXTURES").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        // write-then-rename: tests run in parallel, and the churned fixture
        // is also read by another test — never expose a half-written file
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, &fresh_json).unwrap();
        std::fs::rename(&tmp, &path).unwrap();
        eprintln!(
            "bootstrapped golden fixture {} — commit it (see tests/fixtures/README.md)",
            path.display()
        );
    }
    let on_disk = std::fs::read_to_string(&path).unwrap();

    // 1. The fixture still deserializes, into internally-consistent arenas.
    let loaded = serialize::forest_from_json(&on_disk)
        .unwrap_or_else(|e| panic!("{name}: fixture no longer deserializes: {e}"));
    for t in loaded.trees() {
        t.arena.validate().unwrap();
    }

    // 2. Determinism: the fixture is structurally identical to a forest
    //    rebuilt from the same recipe, with bit-equal predictions.
    assert_eq!(loaded.n_trees(), rebuilt.n_trees(), "{name}: tree count drifted");
    assert_eq!(loaded.n_alive(), rebuilt.n_alive(), "{name}: live count drifted");
    for (a, b) in loaded.trees().iter().zip(rebuilt.trees()) {
        assert_eq!(a.tree_seed, b.tree_seed, "{name}: tree seed drifted");
        assert_eq!(a.epoch, b.epoch, "{name}: epoch drifted");
        assert!(
            a.structural_matches(b),
            "{name}: fixture structure diverged from the deterministic rebuild \
             (an RNG stream or split decision changed)"
        );
    }
    let rows: Vec<Vec<f32>> = (0..60u32).map(|i| rebuilt.data().row(i)).collect();
    assert_eq!(
        loaded.predict_proba_rows(&rows),
        rebuilt.predict_proba_rows(&rows),
        "{name}: predictions drifted"
    );

    // 3. Format stability, both directions: the rebuild serializes to the
    //    fixture bytes, and re-serializing the loaded fixture is a no-op.
    assert_eq!(
        fresh_json, on_disk,
        "{name}: snapshot serialization drifted (schema or emitter change); \
         regenerate deliberately with DARE_UPDATE_FIXTURES=1 and note it"
    );
    assert_eq!(
        serialize::forest_to_json(&loaded),
        on_disk,
        "{name}: load→save roundtrip is not byte-stable"
    );
}

#[test]
fn golden_fresh_snapshot() {
    check_golden("forest_fresh.json", build_fresh());
}

#[test]
fn golden_churned_snapshot() {
    check_golden("forest_churned.json", build_churned());
}

#[test]
fn churned_fixture_supports_further_unlearning() {
    // The fixture isn't just readable — it must stay a *live* model: more
    // deletions apply cleanly and keep the arenas consistent.
    let path = fixture_path("forest_churned.json");
    if !path.exists() {
        // golden_churned_snapshot bootstraps it; don't double-bootstrap here.
        eprintln!("fixture absent (first run); skipping");
        return;
    }
    let mut f = serialize::load(&path).unwrap();
    let live = f.live_ids();
    for &id in live.iter().take(10) {
        f.delete_seq(id).unwrap();
    }
    assert_eq!(f.n_alive(), live.len() - 10);
    for t in f.trees() {
        t.arena.validate().unwrap();
    }
}
