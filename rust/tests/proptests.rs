//! Property-based tests (util::prop) over forest and coordinator invariants:
//! random workloads of trains/deletes/adds must preserve every structural
//! invariant, and the coordinator's routing/batching/state must stay
//! consistent under arbitrary interleavings.

use dare::coordinator::{ServiceConfig, UnlearningService};
use dare::data::dataset::Dataset;
use dare::forest::{DareForest, Node, Params};
use dare::util::json::{parse, Value};
use dare::util::prop::{check, check_sized, gen_feature_column, gen_labels, Config};
use dare::util::rng::Rng;
use std::time::Duration;

fn random_dataset(rng: &mut Rng, n: usize, p: usize) -> Dataset {
    let cols: Vec<Vec<f32>> = (0..p)
        .map(|_| gen_feature_column(rng, n, 0.3, 5.0))
        .collect();
    let pos_rate = 0.2 + 0.6 * rng.f64();
    let labels = gen_labels(rng, n, pos_rate);
    Dataset::from_columns(cols, labels)
}

fn random_params(rng: &mut Rng) -> Params {
    let max_depth = 2 + rng.index(7);
    Params {
        n_trees: 1 + rng.index(3),
        max_depth,
        k: 1 + rng.index(12),
        d_rmax: rng.index(4).min(max_depth),
        ..Default::default()
    }
}

/// Recount every cached statistic from the ground-truth data.
fn assert_node_invariants(node: &Node, d: &Dataset) {
    match node {
        Node::Leaf(l) => {
            assert_eq!(l.n as usize, l.ids.len());
            let pos: u32 = l.ids.iter().map(|&i| d.y(i) as u32).sum();
            assert_eq!(l.n_pos, pos);
            for &id in &l.ids {
                assert!(d.is_alive(id), "leaf holds dead instance {id}");
            }
        }
        Node::Random(r) => {
            assert_eq!(r.n, r.left.n() + r.right.n());
            assert_eq!(r.n_pos, r.left.n_pos() + r.right.n_pos());
            assert_eq!(r.n_left, r.left.n());
            assert_eq!(r.n_right, r.right.n());
            assert!(r.n_left > 0 && r.n_right > 0);
            assert_node_invariants(&r.left, d);
            assert_node_invariants(&r.right, d);
        }
        Node::Greedy(g) => {
            assert_eq!(g.n, g.left.n() + g.right.n());
            assert_eq!(g.n_pos, g.left.n_pos() + g.right.n_pos());
            let mut ids = Vec::new();
            node.collect_ids(None, &mut ids);
            for a in &g.attrs {
                assert!(!a.thresholds.is_empty());
                for t in &a.thresholds {
                    assert!(t.is_valid(), "invalid threshold survived an update");
                    let mut nl = 0u32;
                    let mut nlp = 0u32;
                    let mut clo = 0u32;
                    let mut clop = 0u32;
                    let mut chi = 0u32;
                    let mut chip = 0u32;
                    for &i in &ids {
                        let x = d.x(i, a.attr);
                        let y = d.y(i) as u32;
                        if x <= t.v {
                            nl += 1;
                            nlp += y;
                        }
                        if x == t.v_low {
                            clo += 1;
                            clop += y;
                        } else if x == t.v_high {
                            chi += 1;
                            chip += y;
                        }
                    }
                    assert_eq!(t.n_left, nl);
                    assert_eq!(t.n_left_pos, nlp);
                    assert_eq!(t.n_low, clo);
                    assert_eq!(t.n_low_pos, clop);
                    assert_eq!(t.n_high, chi);
                    assert_eq!(t.n_high_pos, chip);
                }
            }
            assert_node_invariants(&g.left, d);
            assert_node_invariants(&g.right, d);
        }
    }
}

#[test]
fn prop_forest_invariants_under_random_deletion_streams() {
    check_sized(
        "forest invariants under deletions",
        Config {
            cases: 20,
            base_seed: 0xF0_01,
        },
        150,
        |rng, size| {
            let n = size + 10;
            let p = 1 + rng.index(6);
            let data = random_dataset(rng, n, p);
            let params = random_params(rng);
            let mut forest = DareForest::fit(data, &params, rng.next_u64());
            let deletions = rng.index(n);
            for _ in 0..deletions {
                let live = forest.live_ids();
                if live.len() <= 1 {
                    break;
                }
                let id = live[rng.index(live.len())];
                forest.delete_seq(id).unwrap();
            }
            for tree in forest.trees() {
                assert_eq!(tree.n() as usize, forest.n_alive());
                tree.arena.validate().unwrap();
                assert_node_invariants(&tree.root_node(), forest.data());
            }
        },
    );
}

#[test]
fn prop_forest_invariants_under_mixed_add_delete() {
    check_sized(
        "forest invariants under add+delete",
        Config {
            cases: 15,
            base_seed: 0xF0_02,
        },
        100,
        |rng, size| {
            let n = size + 10;
            let p = 1 + rng.index(5);
            let data = random_dataset(rng, n, p);
            let params = random_params(rng);
            let mut forest = DareForest::fit(data, &params, rng.next_u64());
            for _ in 0..30 {
                if rng.bernoulli(0.5) && forest.n_alive() > 2 {
                    let live = forest.live_ids();
                    let id = live[rng.index(live.len())];
                    forest.delete_seq(id).unwrap();
                } else {
                    let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-5.0, 5.0)).collect();
                    forest.add(&row, rng.bernoulli(0.5) as u8);
                }
            }
            for tree in forest.trees() {
                tree.arena.validate().unwrap();
                assert_node_invariants(&tree.root_node(), forest.data());
            }
        },
    );
}

#[test]
fn prop_predictions_always_probabilities() {
    check(
        "predictions in [0,1]",
        Config {
            cases: 25,
            base_seed: 0xF0_03,
        },
        |rng| {
            let n = 20 + rng.index(80);
            let p = 1 + rng.index(4);
            let data = random_dataset(rng, n, p);
            let params = random_params(rng);
            let forest = DareForest::fit(data, &params, rng.next_u64());
            for _ in 0..10 {
                let row: Vec<f32> = (0..forest.data().n_features())
                    .map(|_| rng.range_f32(-100.0, 100.0))
                    .collect();
                let p = forest.predict_proba(&row);
                assert!((0.0..=1.0).contains(&p), "p={p}");
            }
        },
    );
}

#[test]
fn prop_delete_cost_dry_run_never_mutates() {
    check(
        "delete_cost is pure",
        Config {
            cases: 15,
            base_seed: 0xF0_04,
        },
        |rng| {
            let n = 30 + rng.index(100);
            let p = 2 + rng.index(4);
            let data = random_dataset(rng, n, p);
            let params = random_params(rng);
            let forest = DareForest::fit(data, &params, rng.next_u64());
            let probe: Vec<f32> = (0..forest.data().n_features())
                .map(|_| rng.range_f32(-5.0, 5.0))
                .collect();
            let before = forest.predict_proba(&probe);
            for id in forest.live_ids().into_iter().take(20) {
                let _ = forest.delete_cost(id);
            }
            assert_eq!(forest.predict_proba(&probe), before);
        },
    );
}

// ---------------------------------------------------------------------------
// Coordinator invariants: routing, batching, state.
// ---------------------------------------------------------------------------

fn service_with(n: usize, rng: &mut Rng) -> std::sync::Arc<UnlearningService> {
    let data = random_dataset(rng, n, 4);
    let forest = DareForest::fit(
        data,
        &Params {
            n_trees: 2,
            max_depth: 5,
            k: 5,
            ..Default::default()
        },
        rng.next_u64(),
    );
    UnlearningService::new(
        forest,
        ServiceConfig {
            batch_window: Duration::from_millis(1),
            use_pjrt: false,
            ..Default::default()
        },
    )
}

#[test]
fn prop_coordinator_state_consistent_under_request_interleavings() {
    check_sized(
        "coordinator state under interleavings",
        Config {
            cases: 12,
            base_seed: 0xC0_01,
        },
        60,
        |rng, size| {
            let n = size + 20;
            let svc = service_with(n, rng);
            let p = svc.n_features();
            let mut expected_alive = n as i64;
            let mut deleted: std::collections::BTreeSet<u32> = Default::default();
            for _ in 0..25 {
                match rng.index(4) {
                    0 => {
                        // delete a random id (maybe dead/out of range)
                        let id = rng.index(n + 5) as u32;
                        let r = svc.handle(
                            &parse(&format!(r#"{{"op":"delete","ids":[{id}]}}"#)).unwrap(),
                        );
                        if r.get("ok").and_then(Value::as_bool) == Some(true) {
                            let d = r.get("deleted").unwrap().as_u64().unwrap();
                            if d == 1 && deleted.insert(id) {
                                expected_alive -= 1;
                            }
                            // routing invariant: a dead/bogus id is skipped,
                            // never double-deleted
                            if deleted.contains(&id) && d == 1 {
                            } else {
                                assert_eq!(
                                    r.get("skipped").unwrap().as_u64(),
                                    Some(1),
                                    "dead id must be reported skipped"
                                );
                            }
                        }
                    }
                    1 => {
                        // add
                        let row = vec!["0.5"; p].join(",");
                        let r = svc.handle(
                            &parse(&format!(r#"{{"op":"add","row":[{row}],"label":0}}"#))
                                .unwrap(),
                        );
                        assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true));
                        expected_alive += 1;
                    }
                    2 => {
                        // predict never changes state
                        let row = vec!["1.0"; p].join(",");
                        let r = svc.handle(
                            &parse(&format!(r#"{{"op":"predict","rows":[[{row}]]}}"#)).unwrap(),
                        );
                        assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true));
                    }
                    _ => {
                        let r = svc.handle(&parse(r#"{"op":"stats"}"#).unwrap());
                        assert_eq!(
                            r.get("n_alive").and_then(Value::as_u64),
                            Some(expected_alive as u64),
                            "stats must report exact live count"
                        );
                    }
                }
                // global state invariant after every request
                assert_eq!(svc.sharded().n_alive() as i64, expected_alive);
                svc.sharded().for_each_tree(|_, tree| {
                    assert_eq!(tree.n() as i64, expected_alive);
                });
            }
        },
    );
}

#[test]
fn prop_coordinator_batching_equivalent_to_sequential() {
    // Deleting a set through concurrent batched requests must leave exactly
    // the same live-id set as deleting sequentially.
    check(
        "batching equivalence",
        Config {
            cases: 8,
            base_seed: 0xC0_02,
        },
        |rng| {
            let n = 60 + rng.index(60);
            let mut seed_rng = Rng::new(rng.next_u64());
            let svc_batched = service_with(n, &mut seed_rng.clone());
            let svc_seq = service_with(n, &mut seed_rng);
            let n_victims = 10 + rng.index(20);
            let victims: Vec<u32> = rng
                .sample_indices(n, n_victims)
                .into_iter()
                .map(|i| i as u32)
                .collect();

            // batched: concurrent single-id requests
            let svc2 = std::sync::Arc::clone(&svc_batched);
            let handles: Vec<_> = victims
                .iter()
                .map(|&id| {
                    let svc = std::sync::Arc::clone(&svc2);
                    std::thread::spawn(move || {
                        svc.handle(&parse(&format!(r#"{{"op":"delete","ids":[{id}]}}"#)).unwrap())
                    })
                })
                .collect();
            for h in handles {
                let r = h.join().unwrap();
                assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true));
            }

            // sequential
            for &id in &victims {
                svc_seq.handle(&parse(&format!(r#"{{"op":"delete","ids":[{id}]}}"#)).unwrap());
            }

            let a = svc_batched.sharded().live_ids();
            let b = svc_seq.sharded().live_ids();
            assert_eq!(a, b, "batched and sequential deletion must agree on state");
        },
    );
}
