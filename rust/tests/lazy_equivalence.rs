//! ISSUE 4: deferred-unlearning exactness — the lazy pipeline's contract
//! (DESIGN.md §9) as an executable grid over seeds × d_rmax × criteria ×
//! policies:
//!
//! 1. **Flush-all fixpoint**: after any seeded op sequence, flushing every
//!    deferred retrain yields a forest bit-identical to the eager oracle —
//!    per-tree structure, serialized snapshot *bytes*, and predictions.
//! 2. **Serve-time exactness**: every prediction and `delete_cost` served
//!    under `on_read`/`budgeted` (flush-on-read) equals the eager forest's
//!    value at the moment of the query — f32/u64 `==`, no tolerances.
//! 3. **Flush-order invariance**: retrains are path-seeded, so draining
//!    the dirty set in different orders (row-path flushes vs. budgeted
//!    drains vs. flush-all) lands on byte-identical forests.
//!
//! The sharded-store and service layers are covered by `op_fuzz`'s lazy
//! leg and the coordinator tests; this grid pins the forest-level core.

use dare::data::dataset::Dataset;
use dare::forest::serialize::forest_to_json;
use dare::forest::{DareForest, LazyPolicy, MaxFeatures, Params, SplitCriterion};
use dare::util::prop::{gen_feature_column, gen_labels};
use dare::util::rng::{mix_seed, Rng};

fn random_dataset(rng: &mut Rng, n: usize, p: usize) -> Dataset {
    let cols: Vec<Vec<f32>> = (0..p)
        .map(|_| gen_feature_column(rng, n, 0.3, 4.0))
        .collect();
    let labels = gen_labels(rng, n, 0.25 + 0.5 * rng.f64());
    Dataset::from_columns(cols, labels)
}

fn grid_params(d_rmax: usize, criterion: SplitCriterion) -> Params {
    Params {
        n_trees: 2,
        max_depth: 6,
        k: 4,
        d_rmax,
        criterion,
        max_features: MaxFeatures::Sqrt,
        ..Default::default()
    }
}

/// Drive an eager forest and a lazy forest through the same seeded op
/// sequence, asserting serve-time exactness along the way, then flush and
/// assert the bit-identical fixpoint.
fn run_case(seed: u64, d_rmax: usize, criterion: SplitCriterion, policy: LazyPolicy) {
    let mut rng = Rng::new(mix_seed(&[seed, 0x1A2_1]));
    let n = 110 + rng.index(60);
    let p = 4 + rng.index(2);
    let data = random_dataset(&mut rng, n, p);
    let params = grid_params(d_rmax, criterion);
    let forest_seed = rng.next_u64();

    let mut eager = DareForest::fit(data.clone(), &params, forest_seed);
    let mut lazy = DareForest::fit(data, &params, forest_seed);
    lazy.set_lazy_policy(policy);
    assert_eq!(lazy.lazy_policy(), policy);

    let ops = 30 + rng.index(12);
    for op in 0..ops {
        match rng.index(8) {
            0..=4 if eager.n_alive() > 20 => {
                let live = eager.live_ids();
                let id = live[rng.index(live.len())];
                let re = eager.delete_seq(id).unwrap();
                let rl = lazy.delete_seq(id).unwrap();
                // The mark phase reports the identical retrain events and
                // resample counts even though the work is deferred.
                for (a, b) in re.per_tree.iter().zip(&rl.per_tree) {
                    assert_eq!(a.retrain_events, b.retrain_events, "op {op}: events");
                    assert_eq!(
                        a.thresholds_resampled, b.thresholds_resampled,
                        "op {op}: resamples"
                    );
                }
                assert_eq!(re.cost(), rl.cost(), "op {op}: reported cost");
            }
            5 => {
                let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-4.0, 4.0)).collect();
                let label = rng.bernoulli(0.5) as u8;
                assert_eq!(eager.add(&row, label), lazy.add(&row, label), "op {op}: add id");
            }
            6 => {
                // Serve-time cost exactness (as-if-flushed).
                let live = eager.live_ids();
                let id = live[rng.index(live.len())];
                assert_eq!(
                    lazy.delete_cost_flushed(id),
                    eager.delete_cost(id),
                    "op {op}: served delete_cost diverged from eager"
                );
            }
            _ => {
                // Serve-time prediction exactness (flush-on-read), mixing
                // live rows and random probes.
                let live = eager.live_ids();
                let rows: Vec<Vec<f32>> = (0..1 + rng.index(12))
                    .map(|_| {
                        if rng.bernoulli(0.5) {
                            eager.data().row(live[rng.index(live.len())])
                        } else {
                            (0..p).map(|_| rng.range_f32(-5.0, 5.0)).collect()
                        }
                    })
                    .collect();
                assert_eq!(
                    lazy.predict_proba_rows_flushed(&rows),
                    eager.predict_proba_rows(&rows),
                    "op {op}: served predictions diverged from eager"
                );
            }
        }
        for t in lazy.trees() {
            t.validate().unwrap_or_else(|e| panic!("op {op}: lazy tree invalid: {e}"));
        }
        assert_eq!(lazy.n_alive(), eager.n_alive(), "op {op}: live counts");
    }

    // The fixpoint: flush everything → bit-identical to the eager path.
    let flushed = lazy.flush_all();
    assert_eq!(lazy.dirty_subtrees(), 0);
    assert!(
        lazy.flushed_retrains() >= flushed as u64,
        "flush accounting went backwards"
    );
    for (a, b) in eager.trees().iter().zip(lazy.trees()) {
        assert!(
            a.structural_matches(b),
            "seed {seed} d_rmax {d_rmax} {criterion:?} {policy:?}: structure diverged"
        );
        assert_eq!(a.epoch, b.epoch, "epoch counters diverged");
    }
    assert_eq!(
        forest_to_json(&eager),
        forest_to_json(&lazy),
        "seed {seed} d_rmax {d_rmax} {criterion:?} {policy:?}: serialized bytes diverged"
    );
    let probe: Vec<Vec<f32>> = eager
        .live_ids()
        .iter()
        .take(40)
        .map(|&i| eager.data().row(i))
        .collect();
    assert_eq!(eager.predict_proba_rows(&probe), lazy.predict_proba_rows(&probe));
}

#[test]
fn lazy_flush_all_is_bit_identical_to_eager_across_the_grid() {
    for seed in [1u64, 2, 3] {
        for d_rmax in [0usize, 2] {
            for criterion in [SplitCriterion::Gini, SplitCriterion::Entropy] {
                for policy in [LazyPolicy::OnRead, LazyPolicy::Budgeted(2)] {
                    run_case(seed, d_rmax, criterion, policy);
                }
            }
        }
    }
}

/// Flush order cannot change the result: drain the same dirty state three
/// different ways (flush-all, budgeted trickle, read-path row flushes then
/// flush-all) and require byte-identical forests.
#[test]
fn flush_order_is_irrelevant() {
    let mut rng = Rng::new(0xF1_005);
    let data = random_dataset(&mut rng, 160, 5);
    let params = grid_params(1, SplitCriterion::Gini);

    let build_marked = |policy: LazyPolicy| {
        let mut f = DareForest::fit(data.clone(), &params, 99);
        f.set_lazy_policy(policy);
        let mut r = Rng::new(0xBEEF);
        for _ in 0..25 {
            let live = f.live_ids();
            let id = live[r.index(live.len())];
            f.delete_seq(id).unwrap();
        }
        f
    };

    let mut a = build_marked(LazyPolicy::OnRead);
    let mut b = build_marked(LazyPolicy::OnRead);
    let mut c = build_marked(LazyPolicy::OnRead);
    assert_eq!(a.dirty_subtrees(), b.dirty_subtrees());

    // (a) one shot
    a.flush_all();
    // (b) budgeted trickle, one retrain at a time
    while b.dirty_subtrees() > 0 {
        b.compact(1);
    }
    // (c) read-driven: flush along live-row paths first, then finish
    let rows: Vec<Vec<f32>> = c.live_ids().iter().take(30).map(|&i| c.data().row(i)).collect();
    c.predict_proba_rows_flushed(&rows);
    c.flush_all();

    let ja = forest_to_json(&a);
    assert_eq!(ja, forest_to_json(&b), "budgeted drain diverged from flush-all");
    assert_eq!(ja, forest_to_json(&c), "read-driven drain diverged from flush-all");
}

/// ISSUE 8: Occ(q) add-tagging (DESIGN.md §13). Under a lazy policy an
/// *add* owned by a tree lands as pending subtree work in the dirty set —
/// non-owning trees record nothing at all — and draining that backlog, in
/// any order, must land on the same bytes as applying every add eagerly.
/// Grid: q ∈ {0.3, 1.0} × {OnRead, Budgeted(2)} × three drain orders
/// (flush-all, single-step compaction, read-driven then flush-all).
#[test]
fn lazy_add_tagging_drains_to_eager_bytes_across_q() {
    for q in [0.3, 1.0] {
        for policy in [LazyPolicy::OnRead, LazyPolicy::Budgeted(2)] {
            let mut rng = Rng::new(mix_seed(&[0xADD, q.to_bits()]));
            let data = random_dataset(&mut rng, 140, 5);
            let params = grid_params(1, SplitCriterion::Gini).with_subsample(q);

            // Add-heavy sequence: 18 adds interleaved with 6 deletes. Each
            // forest replays it from a fresh rng with the same seed, so all
            // legs see the identical op stream.
            let drive = |f: &mut DareForest| {
                let mut ops = Rng::new(0x0CC_ADD);
                for i in 0..24 {
                    if i % 4 == 3 {
                        let live = f.live_ids();
                        let id = live[ops.index(live.len())];
                        f.delete_seq(id).unwrap();
                    } else {
                        let row: Vec<f32> = (0..5).map(|_| ops.range_f32(-4.0, 4.0)).collect();
                        f.add(&row, ops.bernoulli(0.5) as u8);
                    }
                }
            };

            let mut eager = DareForest::fit(data.clone(), &params, 55);
            drive(&mut eager);

            let build = || {
                let mut f = DareForest::fit(data.clone(), &params, 55);
                f.set_lazy_policy(policy);
                drive(&mut f);
                f
            };
            let mut a = build();
            let mut b = build();
            let mut c = build();
            a.flush_all();
            while b.compact(1) > 0 {}
            let rows: Vec<Vec<f32>> =
                (0..25u32).map(|i| c.data().row(i)).collect();
            c.predict_proba_rows_flushed(&rows);
            c.flush_all();

            let je = forest_to_json(&eager);
            for (name, f) in [("flush-all", &a), ("compact(1)", &b), ("read-driven", &c)] {
                assert_eq!(
                    je,
                    forest_to_json(f),
                    "q={q} {policy:?}: {name} drain diverged from the eager path"
                );
                for t in f.trees() {
                    t.validate().unwrap();
                }
            }
        }
    }
}

/// The deferral counters tell a coherent story: marks raise
/// `dirty_subtrees`/`deferred_retrains`, reads and flushes lower the
/// backlog, and eager mode never defers.
#[test]
fn deferral_counters_track_the_backlog() {
    let mut rng = Rng::new(0xC0DE);
    let data = random_dataset(&mut rng, 150, 5);
    let params = grid_params(0, SplitCriterion::Gini);

    let mut eager = DareForest::fit(data.clone(), &params, 7);
    let mut lazy = DareForest::fit(data, &params, 7);
    lazy.set_lazy_policy(LazyPolicy::OnRead);

    for _ in 0..40 {
        let live = eager.live_ids();
        let id = live[rng.index(live.len())];
        eager.delete_seq(id).unwrap();
        lazy.delete_seq(id).unwrap();
    }
    assert_eq!(eager.dirty_subtrees(), 0, "eager mode must never defer");
    assert_eq!(eager.deferred_retrains(), 0);
    assert!(
        lazy.deferred_retrains() > 0,
        "30 deletions should defer at least one retrain"
    );
    assert_eq!(
        lazy.dirty_subtrees() as u64,
        lazy.deferred_retrains() - lazy.flushed_retrains(),
        "backlog must equal deferred minus flushed"
    );
    let backlog = lazy.dirty_subtrees();
    let drained = lazy.flush_all();
    assert_eq!(drained, backlog);
    assert_eq!(lazy.dirty_subtrees(), 0);
    assert_eq!(lazy.deferred_retrains(), lazy.flushed_retrains());
    // Switching back to eager on a clean forest keeps everything exact.
    lazy.set_lazy_policy(LazyPolicy::Eager);
    let live = lazy.live_ids();
    lazy.delete_seq(live[0]).unwrap();
    eager.delete_seq(live[0]).unwrap();
    for (a, b) in eager.trees().iter().zip(lazy.trees()) {
        assert!(a.structural_matches(b));
    }
}
