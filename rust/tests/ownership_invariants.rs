//! ISSUE 8: Occ(q) ownership invariants (DESIGN.md §13) as seeded property
//! tests over the stateless predicate `owns(tree_seed, id, q)`:
//!
//! 1. **Binomial mass** — each instance is owned by a Binomial(T, q)
//!    number of trees (tolerance-banded means, per-tree calibration, and
//!    monotonicity in q).
//! 2. **Non-owner isolation** — deleting an instance leaves every
//!    non-owning tree's arena untouched: epoch unchanged, serialized bytes
//!    unchanged.
//! 3. **Persistence** — ownership survives save/load (the loader
//!    revalidates every tree's leaf id set against the predicate) and lazy
//!    flush-order permutations (drain orders land on byte-identical
//!    forests).
//! 4. **Zero-cost unowned ids** — `delete_cost` of an instance owned by no
//!    tree is exactly 0, on both the forest and the sharded store, and
//!    deleting it moves no tree epoch and no shard epoch.

use dare::coordinator::ShardedForest;
use dare::data::dataset::{Dataset, InstanceId};
use dare::forest::forest::tree_seed;
use dare::forest::serialize::{forest_to_json, load, save};
use dare::forest::{owned_live_ids, owns, DareForest, LazyPolicy, Params};
use dare::util::json::parse;
use dare::util::prop::{gen_feature_column, gen_labels};
use dare::util::rng::{mix_seed, Rng};

fn random_dataset(rng: &mut Rng, n: usize, p: usize) -> Dataset {
    let cols: Vec<Vec<f32>> = (0..p)
        .map(|_| gen_feature_column(rng, n, 0.3, 4.0))
        .collect();
    let labels = gen_labels(rng, n, 0.25 + 0.5 * rng.f64());
    Dataset::from_columns(cols, labels)
}

fn params(n_trees: usize, q: f64) -> Params {
    Params {
        n_trees,
        max_depth: 6,
        k: 5,
        ..Default::default()
    }
    .with_subsample(q)
}

#[test]
fn ownership_mass_is_binomial_in_the_tree_count() {
    const T: usize = 40;
    const IDS: u32 = 2_000;
    let seeds: Vec<u64> = (0..T).map(|t| tree_seed(0xB10_0D, t)).collect();
    for q in [0.1, 0.3, 0.5] {
        // Mean owners per instance ≈ qT (Binomial mean; se of the sample
        // mean over 2000 ids is ~0.07 trees, the band is ±0.5).
        let mut total_owned = 0usize;
        for id in 0..IDS {
            total_owned += seeds.iter().filter(|&&ts| owns(ts, id, q)).count();
        }
        let mean = total_owned as f64 / IDS as f64;
        assert!(
            (mean - q * T as f64).abs() < 0.5,
            "q={q}: mean owners/instance {mean} strays from {}",
            q * T as f64
        );
        // Per-tree calibration: each tree owns ≈ q of the corpus
        // (2000 draws → se ≈ 0.011 at q=0.5; band ±0.05).
        for &ts in &seeds {
            let frac = (0..IDS).filter(|&id| owns(ts, id, q)).count() as f64 / IDS as f64;
            assert!(
                (frac - q).abs() < 0.05,
                "tree seed {ts}: owned fraction {frac} strays from q={q}"
            );
        }
    }
    // Monotone in q (shared hash, growing threshold): an owner at q stays
    // an owner at every larger q, and q=1.0 owns everything.
    for id in 0..200u32 {
        for &ts in seeds.iter().take(5) {
            let mut prev = false;
            for q in [0.1, 0.3, 0.5, 0.9, 1.0] {
                let now = owns(ts, id, q);
                assert!(now || !prev, "ownership must be monotone in q");
                prev = now;
            }
            assert!(owns(ts, id, 1.0));
        }
    }
}

/// Per-tree JSON objects of a serialized forest (epoch + full structure),
/// so byte-level "untouched" is checkable tree by tree.
fn tree_bytes(f: &DareForest) -> Vec<String> {
    let v = parse(&forest_to_json(f)).unwrap();
    v.get("trees")
        .and_then(|t| t.as_arr())
        .unwrap()
        .iter()
        .map(|t| t.to_string())
        .collect()
}

#[test]
fn deleting_an_instance_leaves_non_owning_trees_untouched() {
    let q = 0.3;
    let mut rng = Rng::new(mix_seed(&[0x0CC, 1]));
    let data = random_dataset(&mut rng, 160, 5);
    let mut f = DareForest::fit(data, &params(8, q), 9001);

    // Pick a live id with mixed ownership so both branches are exercised.
    let target = (0..160u32)
        .find(|&id| {
            let owners = f.trees().iter().filter(|t| owns(t.tree_seed, id, q)).count();
            owners > 0 && owners < f.n_trees()
        })
        .expect("some id must have mixed ownership at q=0.3 over 8 trees");
    let owners: Vec<bool> = f
        .trees()
        .iter()
        .map(|t| owns(t.tree_seed, target, q))
        .collect();

    let epochs_before: Vec<u64> = f.trees().iter().map(|t| t.epoch).collect();
    let bytes_before = tree_bytes(&f);
    let report = f.delete(target).unwrap().per_tree;
    let bytes_after = tree_bytes(&f);

    assert_eq!(report.len(), f.n_trees(), "report arity must stay T");
    for (t, owned) in owners.iter().enumerate() {
        if *owned {
            assert_eq!(
                f.trees()[t].epoch,
                epochs_before[t] + 1,
                "owning tree {t} must advance its epoch"
            );
        } else {
            assert_eq!(
                f.trees()[t].epoch, epochs_before[t],
                "non-owning tree {t} must not move its epoch"
            );
            assert_eq!(
                bytes_after[t], bytes_before[t],
                "non-owning tree {t} must serialize to identical bytes"
            );
            assert!(
                report[t].retrain_events.is_empty() && report[t].cost() == 0,
                "non-owning tree {t} must report an empty delete"
            );
        }
    }
    for t in f.trees() {
        t.validate().unwrap();
        assert_eq!(
            t.n() as usize,
            owned_live_ids(f.data(), t.tree_seed, q).len(),
            "tree invariant: n == |live ∩ owned|"
        );
    }
}

#[test]
fn ownership_survives_save_load_and_flush_order_permutations() {
    let q = 0.3;
    let build = || {
        let mut rng = Rng::new(mix_seed(&[0x0CC, 2]));
        let data = random_dataset(&mut rng, 150, 5);
        let mut f = DareForest::fit(data, &params(6, q), 4242);
        f.set_lazy_policy(LazyPolicy::OnRead);
        f
    };
    let mut a = build();
    let mut b = build();
    let mut c = build();
    let ops: Vec<u32> = vec![3, 17, 44, 90, 120, 31, 66];
    for f in [&mut a, &mut b, &mut c] {
        for &id in &ops {
            f.delete(id).unwrap();
        }
        let p = f.data().n_features();
        for i in 0..4 {
            f.add(&vec![0.2 * i as f32; p], (i % 2) as u8);
        }
    }
    // Three drain orders: one-shot, single-step compaction loop, and
    // read-driven flushing first.
    a.flush_all();
    while b.compact(1) > 0 {}
    let rows: Vec<Vec<f32>> = (0..30u32).map(|i| c.data().row(i)).collect();
    c.predict_proba_rows_flushed(&rows);
    c.flush_all();
    let ja = forest_to_json(&a);
    assert_eq!(ja, forest_to_json(&b), "compact(1) drain order diverged");
    assert_eq!(ja, forest_to_json(&c), "read-driven drain order diverged");

    // Save/load: the loader revalidates every tree's leaf id set against
    // the ownership predicate, and the counts and bytes survive.
    let tmp = std::env::temp_dir().join("dare_ownership_invariants.json");
    save(&a, &tmp).unwrap();
    let back = load(&tmp).unwrap();
    std::fs::remove_file(&tmp).ok();
    assert_eq!(back.params().q, q);
    assert_eq!(back.ownership_counts(), a.ownership_counts());
    for (t, back_t) in a.trees().iter().zip(back.trees()) {
        assert!(t.structural_matches(back_t));
    }
    // The persisted ownership sets are exactly what the predicate derives.
    for t in back.trees() {
        let expect = owned_live_ids(back.data(), t.tree_seed, q);
        assert_eq!(t.n() as usize, expect.len());
    }
}

#[test]
fn unowned_everywhere_id_costs_zero_and_moves_nothing() {
    let q = 0.1;
    let mut rng = Rng::new(mix_seed(&[0x0CC, 3]));
    let data = random_dataset(&mut rng, 140, 5);
    let p = params(3, q);
    let mut f = DareForest::fit(data.clone(), &p, 77);
    let orphan: InstanceId = (0..140u32)
        .find(|&id| f.trees().iter().all(|t| !owns(t.tree_seed, id, q)))
        .expect("q=0.1 over 3 trees leaves ~73% of ids unowned everywhere");

    assert_eq!(f.delete_cost(orphan), 0, "unowned-everywhere id must cost 0");
    let epochs_before: Vec<u64> = f.trees().iter().map(|t| t.epoch).collect();
    let report = f.delete(orphan).unwrap();
    assert_eq!(report.cost(), 0);
    assert_eq!(report.retrain_events(), 0);
    let epochs_after: Vec<u64> = f.trees().iter().map(|t| t.epoch).collect();
    assert_eq!(epochs_before, epochs_after, "no tree may move for an orphan");
    assert!(!f.data().is_alive(orphan), "the instance still leaves the corpus");
    for t in f.trees() {
        t.validate().unwrap();
    }

    // Sharded store: same zero cost, and a zero-owner delete moves no
    // shard epoch (the fan-out routes to owning shards only).
    let sharded = ShardedForest::new(DareForest::fit(data, &p, 77), 2);
    let orphan2 = (0..140u32)
        .find(|&id| {
            sharded.with_data(|d| d.is_alive(id))
                && {
                    let mut unowned = true;
                    sharded.for_each_tree(|_, t| unowned &= !owns(t.tree_seed, id, q));
                    unowned
                }
        })
        .unwrap();
    assert_eq!(sharded.delete_cost(orphan2).unwrap(), 0);
    let before = sharded.shard_epochs();
    let (rep, skipped) = sharded.delete_batch(&[orphan2]);
    assert_eq!(skipped, 0, "the id is live — accepted, just unowned");
    assert!(rep.per_tree.iter().all(|r| r.cost() == 0));
    assert_eq!(sharded.shard_epochs(), before, "no shard may republish");
    assert_eq!(sharded.n_alive(), 139);
    sharded.validate().unwrap();
}
