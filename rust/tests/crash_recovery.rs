//! ISSUE 6: crash-fault injection for the write-ahead log (DESIGN.md §11).
//!
//! A crash can stop the process after ANY byte of the log. These tests
//! simulate that directly on the on-disk artifacts: journal a known op
//! sequence, then for every possible truncation point (a torn tail from a
//! mid-append kill) and for targeted byte corruptions, recover and check
//! the invariant the recovery protocol promises:
//!
//!   recovery always lands on the state after some *prefix* of complete,
//!   durably-framed records — never a half-applied op, never a panic —
//!   and drops the torn tail so subsequent appends extend a valid log.
//!
//! The expected state for each prefix is captured live (the serialized
//! forest after each op), so the comparison is byte-exact and independent
//! of the recovery code under test.

use dare::coordinator::api::Op;
use dare::coordinator::wal::{dir_name, Wal, LOG_FILE, NAME_FILE, SNAPSHOT_FILE};
use dare::coordinator::FsyncPolicy;
use dare::data::synth::{generate, SynthSpec};
use dare::forest::serialize::forest_to_json;
use dare::forest::{DareForest, Params};
use std::path::{Path, PathBuf};

const KEY: &[u8] = b"crash-test-key";
const MODEL: &str = "crash";

fn temp_root(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dare-crash-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn fit_forest(seed: u64) -> DareForest {
    let d = generate(
        &SynthSpec {
            n: 90,
            informative: 3,
            redundant: 0,
            noise: 2,
            flip: 0.05,
            ..Default::default()
        },
        seed,
    );
    DareForest::fit(
        d,
        &Params {
            n_trees: 3,
            max_depth: 5,
            k: 5,
            ..Default::default()
        },
        seed ^ 0x51,
    )
}

/// Journal a fixed op sequence; return, per op count k, the byte length
/// of the log holding exactly k records and the serialized state after
/// those k ops. (`snapshot_every: 0` so the log is never truncated and
/// every prefix stays addressable.)
fn build_journal(root: &Path) -> (Vec<u64>, Vec<String>) {
    let mut live = fit_forest(11);
    let wal = Wal::create(root, MODEL, &live, FsyncPolicy::EveryOp, 0, KEY.to_vec()).unwrap();
    let log = root.join(dir_name(MODEL)).join(LOG_FILE);
    let mut offsets = vec![std::fs::metadata(&log).unwrap().len()];
    let mut states = vec![forest_to_json(&live)];

    let p = live.data().n_features();
    let ops: Vec<Op> = vec![
        Op::Delete { ids: vec![3, 7] },
        Op::Add {
            row: vec![0.25; p],
            label: 1,
        },
        Op::Delete { ids: vec![15] },
        Op::Delete { ids: vec![15, 21] }, // 15 now dead: replay must skip it too
        Op::Add {
            row: vec![-1.5; p],
            label: 0,
        },
        Op::Delete { ids: vec![40, 41, 42] },
    ];
    for op in ops {
        wal.logged(
            op.clone(),
            || match &op {
                Op::Delete { ids } => {
                    live.delete_batch(ids);
                }
                Op::Add { row, label } => {
                    live.add(row, *label);
                }
                _ => unreachable!(),
            },
            || unreachable!("snapshot_every is 0"),
        )
        .unwrap();
        offsets.push(std::fs::metadata(&log).unwrap().len());
        states.push(forest_to_json(&live));
    }
    drop(wal);
    (offsets, states)
}

/// Copy the model dir, overwriting the log with `log_bytes`.
fn install_variant(src_root: &Path, dst_root: &Path, log_bytes: &[u8]) {
    let src = src_root.join(dir_name(MODEL));
    let dst = dst_root.join(dir_name(MODEL));
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for f in [SNAPSHOT_FILE, NAME_FILE] {
        std::fs::copy(src.join(f), dst.join(f)).unwrap();
    }
    std::fs::write(dst.join(LOG_FILE), log_bytes).unwrap();
}

fn recover(root: &Path) -> anyhow::Result<dare::coordinator::wal::Recovered> {
    Wal::recover(root, &dir_name(MODEL), FsyncPolicy::EveryOp, 0, KEY.to_vec())
}

/// Largest k with offsets[k] <= cut: the number of complete records a
/// log truncated at `cut` bytes still holds (cut below the header ⇒ 0).
fn prefix_ops(offsets: &[u64], cut: u64) -> usize {
    offsets.iter().rposition(|&o| o <= cut).unwrap_or(0)
}

#[test]
fn recovery_survives_truncation_at_every_byte_offset() {
    let src = temp_root("trunc-src");
    let (offsets, states) = build_journal(&src);
    let log_bytes = std::fs::read(src.join(dir_name(MODEL)).join(LOG_FILE)).unwrap();
    assert_eq!(*offsets.last().unwrap(), log_bytes.len() as u64);

    let dst = temp_root("trunc-dst");
    for cut in 0..=log_bytes.len() {
        install_variant(&src, &dst, &log_bytes[..cut]);
        let rec = recover(&dst)
            .unwrap_or_else(|e| panic!("cut {cut}: recovery must absorb torn tails: {e}"));
        let k = prefix_ops(&offsets, cut as u64);
        assert_eq!(
            forest_to_json(&rec.forest),
            states[k],
            "cut {cut}: expected the state after {k} complete records"
        );
        assert_eq!(rec.replayed, k as u64, "cut {cut}: replay count");
        assert_eq!(rec.wal.epoch(), k as u64, "cut {cut}: epoch");
        // the torn tail is gone from disk: either the valid prefix
        // remains, or (unreadable header) a fresh header was written
        let len = std::fs::metadata(dst.join(dir_name(MODEL)).join(LOG_FILE))
            .unwrap()
            .len();
        assert_eq!(len, offsets[k].max(16), "cut {cut}: tail not dropped");
    }
    let _ = std::fs::remove_dir_all(&src);
    let _ = std::fs::remove_dir_all(&dst);
}

#[test]
fn recovery_survives_single_byte_corruption() {
    let src = temp_root("corrupt-src");
    let (offsets, states) = build_journal(&src);
    let log_bytes = std::fs::read(src.join(dir_name(MODEL)).join(LOG_FILE)).unwrap();
    let dst = temp_root("corrupt-dst");

    // Flip a payload byte inside each record in turn: everything before
    // the corrupted record survives, it and everything after is dropped
    // (the epoch chain prevents resynchronizing past a hole).
    for k in 0..offsets.len() - 1 {
        let mut bytes = log_bytes.clone();
        let pos = (offsets[k] + 12) as usize; // inside record k+1's payload
        bytes[pos] ^= 0x40;
        install_variant(&src, &dst, &bytes);
        let rec = recover(&dst).unwrap();
        assert_eq!(
            forest_to_json(&rec.forest),
            states[k],
            "corruption in record {}: expected the state after {k} records",
            k + 1
        );
    }

    // A corrupted header drops the whole log but never the snapshot.
    let mut bytes = log_bytes.clone();
    bytes[3] ^= 0xff;
    install_variant(&src, &dst, &bytes);
    let rec = recover(&dst).unwrap();
    assert_eq!(forest_to_json(&rec.forest), states[0]);
    // ... and the rewritten log accepts appends again: journal one op on
    // the recovered WAL and recover a second time.
    let mut wal = rec.wal;
    wal.set_model(MODEL);
    let mut live = dare::forest::serialize::forest_from_json(&states[0]).unwrap();
    wal.logged(
        Op::Delete { ids: vec![2] },
        || {
            live.delete_batch(&[2]);
        },
        || unreachable!("snapshot_every is 0"),
    )
    .unwrap();
    drop(wal);
    let rec2 = recover(&dst).unwrap();
    assert_eq!(forest_to_json(&rec2.forest), forest_to_json(&live));
    assert_eq!(rec2.wal.epoch(), 1);

    let _ = std::fs::remove_dir_all(&src);
    let _ = std::fs::remove_dir_all(&dst);
}

#[test]
fn corrupt_snapshot_is_a_structured_error_and_stray_files_are_ignored() {
    let root = temp_root("snapshot");
    let (_, _) = build_journal(&root);
    let dir = root.join(dir_name(MODEL));

    // stray files and temp droppings don't confuse the scan
    std::fs::write(root.join("stray.txt"), b"not a model").unwrap();
    std::fs::create_dir_all(root.join("empty-dir")).unwrap();
    std::fs::write(dir.join(".snapshot.json.tmp"), b"torn temp").unwrap();
    assert_eq!(Wal::scan(&root), vec![dir_name(MODEL)]);
    recover(&root).expect("temp droppings must not break recovery");

    // a corrupt snapshot is a structured error, not a panic
    let snap = std::fs::read(dir.join(SNAPSHOT_FILE)).unwrap();
    std::fs::write(dir.join(SNAPSHOT_FILE), &snap[..snap.len() / 2]).unwrap();
    let err = recover(&root).expect_err("half a snapshot must not recover");
    assert!(
        err.to_string().contains(SNAPSHOT_FILE),
        "error should name the snapshot: {err}"
    );
    let _ = std::fs::remove_dir_all(&root);
}
