//! Integration suite for the DESIGN.md §15 deadline-aware scheduler,
//! driven through the real coordinator stack: a registry-backed
//! [`UnlearningService`] with a [`Scheduler`] attached, wire-codec
//! requests, and the background runner thread where noted.
//!
//! The virtual-clock unit suite (in `coordinator::scheduler`) owns the
//! tight algorithmic bounds — EDF order, DRR weights, the exact budget
//! overrun bound. This file owns the wiring claims:
//!
//! 1. a scheduled service serves byte-identical responses to a direct
//!    `handle()` twin (the ISSUE's exactness acceptance, in miniature —
//!    the fuzz-grid version lives in `op_fuzz.rs` leg 5);
//! 2. the stats surface reports scheduler queue state per tenant;
//! 3. admission refusals travel the wire as `overloaded` with a
//!    `retry_after_ms` hint and decode back to [`ApiError::Overloaded`];
//! 4. background compact *bids* drain a deferred-retrain backlog in
//!    slack, observably (telemetry tick counters, `executed_bg`).

use dare::coordinator::api::{error_from_wire, ApiError};
use dare::coordinator::{
    Scheduler, SchedulerConfig, ServiceConfig, Submitted, UnlearningService,
};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, LazyPolicy, Params};
use dare::util::json::{parse, Value};
use std::time::Duration;

fn corpus(n: usize, seed: u64) -> dare::data::dataset::Dataset {
    generate(
        &SynthSpec {
            n,
            informative: 4,
            redundant: 2,
            noise: 4,
            flip: 0.05,
            ..Default::default()
        },
        seed,
    )
}

fn forest(n: usize, seed: u64) -> DareForest {
    let params = Params {
        n_trees: 3,
        max_depth: 5,
        k: 5,
        d_rmax: 1,
        ..Default::default()
    };
    DareForest::fit(corpus(n, seed), &params, seed ^ 0xF0)
}

fn service_config(lazy: LazyPolicy) -> ServiceConfig {
    ServiceConfig {
        batch_window: Duration::from_millis(1),
        use_pjrt: false,
        n_shards: 2,
        lazy,
        // Park the interval compactor: these tests drive compaction
        // explicitly (through bids) so its timing must not race.
        compact_interval: Duration::from_secs(3600),
        ..Default::default()
    }
}

fn req(s: &str) -> Value {
    parse(s).unwrap()
}

/// Identical forests behind two services — one raw, one scheduled with
/// the runner thread draining the queue — must serve byte-identical
/// responses for the same op stream (per-tenant FIFO is the submission
/// order here, so cross-tenant reordering cannot show through).
#[test]
fn scheduled_service_serves_identical_bytes_to_direct_handle() {
    let policy = LazyPolicy::from_env();
    let mk = || {
        UnlearningService::with_models(
            vec![
                ("alpha".to_string(), forest(90, 21)),
                ("beta".to_string(), forest(70, 22)),
            ],
            service_config(policy),
        )
    };
    let direct = mk();
    let scheduled = mk();
    let sched = Scheduler::attach(&scheduled, SchedulerConfig::default());
    Scheduler::spawn_runner(&sched);

    let live: Vec<u64> = {
        let model = direct.registry().get("alpha").unwrap();
        let ids = model.sharded().live_ids();
        ids.iter().take(6).map(|&i| i as u64).collect()
    };
    let mut ops = vec![
        r#"{"v":1,"model":"alpha","op":"predict","rows":[[0.5,-1.0,2.0,0.0,1.0,-0.5,0.25,1.5,-2.0,0.75]]}"#.to_string(),
        r#"{"v":1,"model":"beta","op":"predict","rows":[[1.0,1.0,1.0,1.0,1.0,1.0,1.0,1.0,1.0,1.0],[0.0,0.0,0.0,0.0,0.0,0.0,0.0,0.0,0.0,0.0]]}"#.to_string(),
    ];
    for &id in &live[..3] {
        ops.push(format!(r#"{{"v":1,"model":"alpha","op":"delete_cost","id":{id}}}"#));
        ops.push(format!(r#"{{"v":1,"model":"alpha","op":"delete","ids":[{id}]}}"#));
        ops.push(
            r#"{"v":1,"model":"alpha","op":"predict","rows":[[0.5,-1.0,2.0,0.0,1.0,-0.5,0.25,1.5,-2.0,0.75]]}"#
                .to_string(),
        );
    }
    ops.push(r#"{"v":1,"model":"alpha","op":"flush"}"#.to_string());
    ops.push(r#"{"v":1,"model":"beta","op":"compact","budget":4}"#.to_string());

    for (i, op) in ops.iter().enumerate() {
        let wire = req(op);
        let want = direct.handle(&wire).to_string();
        let got = sched.handle(&wire).to_string();
        assert_eq!(got, want, "op {i} diverged between direct and scheduled serving");
    }
    sched.shutdown();
}

/// With a scheduler attached, the stats payload gains a `sched` object
/// describing that tenant's queue — depth, weight, execution counters.
#[test]
fn stats_surface_reports_scheduler_queue_state() {
    let svc = UnlearningService::with_models(
        vec![("m".to_string(), forest(80, 31))],
        service_config(LazyPolicy::Eager),
    );

    // Before attach: no sched key (pinned v0 stats shape is untouched).
    let plain = svc.handle(&req(r#"{"v":1,"model":"m","op":"stats"}"#));
    assert!(plain.get("sched").is_none());

    let mut cfg = SchedulerConfig::default();
    cfg.weights.insert("m".to_string(), 2.0);
    let sched = Scheduler::attach(&svc, cfg);

    // Queue one predict (no runner: it stays queued while we look).
    let queued = sched
        .submit(&req(
            r#"{"v":1,"model":"m","op":"predict","rows":[[0,0,0,0,0,0,0,0,0,0]]}"#,
        ))
        .unwrap();
    let Submitted::Queued(rx) = queued else {
        panic!("predict must queue, not bypass");
    };

    let stats = svc.handle(&req(r#"{"v":1,"model":"m","op":"stats"}"#));
    let s = stats.get("sched").expect("stats must report scheduler state");
    assert_eq!(s.get("queued").and_then(|v| v.as_u64()), Some(1));
    assert_eq!(s.get("queued_bg").and_then(|v| v.as_u64()), Some(0));
    assert_eq!(s.get("weight").and_then(|v| v.as_f64()), Some(2.0));
    assert_eq!(s.get("executed").and_then(|v| v.as_u64()), Some(0));

    let report = sched.run_for(Duration::from_millis(50));
    assert_eq!(report.executed, 1);
    assert_eq!(
        rx.recv().unwrap().get("ok").and_then(|v| v.as_bool()),
        Some(true)
    );
    let after = svc.handle(&req(r#"{"v":1,"model":"m","op":"stats"}"#));
    let s = after.get("sched").unwrap();
    assert_eq!(s.get("queued").and_then(|v| v.as_u64()), Some(0));
    assert_eq!(s.get("executed").and_then(|v| v.as_u64()), Some(1));
}

/// Past the per-tenant depth bound, `handle` answers immediately with the
/// wire `overloaded` error carrying a positive `retry_after_ms`, and the
/// typed decode round-trips.
#[test]
fn admission_refusal_travels_the_wire() {
    let svc = UnlearningService::with_models(
        vec![("m".to_string(), forest(80, 41))],
        service_config(LazyPolicy::Eager),
    );
    let mut cfg = SchedulerConfig::default();
    cfg.queue_depth = 2;
    let sched = Scheduler::attach(&svc, cfg);

    let predict = req(r#"{"v":1,"model":"m","op":"predict","rows":[[0,0,0,0,0,0,0,0,0,0]]}"#);
    let _rx1 = match sched.submit(&predict).unwrap() {
        Submitted::Queued(rx) => rx,
        Submitted::Immediate(_) => panic!("predict must queue"),
    };
    let _rx2 = match sched.submit(&predict).unwrap() {
        Submitted::Queued(rx) => rx,
        Submitted::Immediate(_) => panic!("predict must queue"),
    };

    // Third submission: refused, typed.
    let err = sched.submit(&predict).expect_err("depth 2 must refuse the third");
    let ApiError::Overloaded { retry_after_ms } = &err else {
        panic!("expected Overloaded, got {err:?}");
    };
    assert!(*retry_after_ms >= 1);

    // Same refusal through the blocking wire front door.
    let wire = sched.handle(&predict);
    assert_eq!(wire.get("ok").and_then(|v| v.as_bool()), Some(false));
    let decoded = error_from_wire(&wire);
    assert!(matches!(decoded, ApiError::Overloaded { retry_after_ms } if retry_after_ms >= 1));

    // The refusal is observable per tenant.
    let stats = sched.tenant_stats("m");
    assert_eq!(stats.get("overloaded").and_then(|v| v.as_u64()), Some(2));
}

/// The rewritten compactor path: a deferred-retrain backlog built by lazy
/// deletes is drained by a background *bid* that only runs in slack, and
/// every tick lands in telemetry (`compact_ticks`, `compact_spent_us`)
/// and the per-tenant scheduler counters (`executed_bg`).
#[test]
fn compact_bids_drain_the_backlog_in_slack() {
    let svc = UnlearningService::with_models(
        vec![("m".to_string(), forest(140, 51))],
        service_config(LazyPolicy::OnRead),
    );
    let sched = Scheduler::attach(&svc, SchedulerConfig::default());

    // Build a backlog: lazy deletes defer structural retrains. Submit the
    // whole burst, then drain with explicit budget cycles (no runner
    // thread — the cycles are the observable under test).
    let model = svc.registry().get("m").unwrap();
    let live = model.sharded().live_ids();
    let mut pending = Vec::new();
    for chunk in live[..40.min(live.len())].chunks(4) {
        let ids: Vec<String> = chunk.iter().map(|id| id.to_string()).collect();
        let wire = req(&format!(
            r#"{{"v":1,"model":"m","op":"delete","ids":[{}]}}"#,
            ids.join(",")
        ));
        match sched.submit(&wire).unwrap() {
            Submitted::Queued(rx) => pending.push(rx),
            Submitted::Immediate(_) => panic!("delete must queue"),
        }
    }
    while sched.queued_total() > 0 {
        sched.run_for(Duration::from_millis(10));
    }
    for rx in pending {
        assert_eq!(
            rx.recv().unwrap().get("ok").and_then(|v| v.as_bool()),
            Some(true)
        );
    }

    // Bid for slack; a second bid before it runs dedupes.
    assert!(sched.bid_compact("m", 10_000), "first bid must be accepted");
    assert!(!sched.bid_compact("m", 10_000), "outstanding bid must dedupe");
    let report = sched.run_for(Duration::from_millis(500));
    assert_eq!(report.executed_bg, 1, "slack cycle must run the bid");

    // Backlog drained; every tick observable in telemetry and the
    // per-tenant scheduler counters.
    assert_eq!(model.sharded().pending_retrains(), 0);
    assert!(model.telemetry().counter("compact_ticks") >= 1);
    let ts = sched.tenant_stats("m");
    assert_eq!(ts.get("executed_bg").and_then(|v| v.as_u64()), Some(1));
    assert!(ts.get("compact_ticks").and_then(|v| v.as_u64()) >= Some(1));
    assert!(sched.queued_total() == 0 && !sched.pending_bid("m"));
}
