//! ISSUE 2 acceptance: arena consistency under delete-heavy churn.
//!
//! Hundreds of interleaved deletions and additions must leave every tree's
//! arena fully consistent — free lists and node ids audited by
//! `ArenaTree::validate` (no leaked slots, no double-frees, hot/cold planes
//! in agreement) — with `memory()` totals stable (slot reuse, not unbounded
//! growth), snapshots that round-trip structurally, and the churned forest
//! still bit-exact with a forest that applied the same operations on a
//! boxed-oracle schedule.

use dare::data::synth::{generate, SynthSpec};
use dare::forest::{serialize, DareForest, Params};
use dare::util::rng::Rng;

fn forest(n: usize, n_trees: usize, d_rmax: usize, seed: u64) -> DareForest {
    let data = generate(
        &SynthSpec {
            n,
            informative: 4,
            redundant: 1,
            noise: 3,
            flip: 0.08,
            ..Default::default()
        },
        seed,
    );
    let params = Params {
        n_trees,
        max_depth: 7,
        k: 5,
        d_rmax,
        ..Default::default()
    };
    DareForest::fit(data, &params, seed ^ 0xA11CE)
}

#[test]
fn heavy_churn_keeps_arenas_consistent_and_memory_stable() {
    let mut f = forest(500, 4, 2, 1);
    let p = f.data().n_features();
    let fresh_total = f.memory().total();
    let mut rng = Rng::new(7);
    let mut peak_total = fresh_total;
    for step in 0..400 {
        if f.n_alive() > 60 && rng.bernoulli(0.6) {
            let live = f.live_ids();
            let id = live[rng.index(live.len())];
            f.delete_seq(id).unwrap();
        } else {
            let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-4.0, 4.0)).collect();
            f.add(&row, rng.bernoulli(0.5) as u8);
        }
        peak_total = peak_total.max(f.memory().total());
        if step % 25 == 0 {
            for t in f.trees() {
                t.arena.validate().unwrap_or_else(|e| {
                    panic!("arena inconsistent at step {step}: {e}")
                });
            }
        }
    }
    for t in f.trees() {
        t.arena.validate().unwrap();
        // no leaks: every slot is live or on the free list (validate checks
        // the exact partition); the arena does not balloon past the peak
        // live size — slots are recycled.
        assert!(t.arena.free_len() < t.arena.len());
    }
    // Memory is stable: the churned forest's footprint stays within the
    // envelope of what it actually had to hold at peak, and the peak itself
    // is bounded by a small multiple of the fresh model (the dataset only
    // fluctuated around its initial size).
    let end_total = f.memory().total();
    assert!(end_total <= peak_total);
    assert!(
        peak_total < fresh_total * 3,
        "arena memory ballooned: fresh {fresh_total} → peak {peak_total}"
    );
}

#[test]
fn churned_snapshot_roundtrips_with_exact_predictions() {
    let mut f = forest(300, 3, 1, 2);
    let p = f.data().n_features();
    let mut rng = Rng::new(11);
    for _ in 0..150 {
        if f.n_alive() > 40 && rng.bernoulli(0.65) {
            let live = f.live_ids();
            let id = live[rng.index(live.len())];
            f.delete_seq(id).unwrap();
        } else {
            let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-4.0, 4.0)).collect();
            f.add(&row, rng.bernoulli(0.5) as u8);
        }
    }
    let back = serialize::forest_from_json(&serialize::forest_to_json(&f)).unwrap();
    assert_eq!(back.n_alive(), f.n_alive());
    for (a, b) in f.trees().iter().zip(back.trees()) {
        assert!(a.structural_matches(b), "roundtrip changed tree structure");
        b.arena.validate().unwrap();
    }
    let rows: Vec<Vec<f32>> = (0..80u32).map(|i| f.data().row(i)).collect();
    assert_eq!(
        f.predict_proba_rows(&rows),
        back.predict_proba_rows(&rows),
        "roundtrip changed predictions"
    );
    // the restored forest keeps supporting exact unlearning
    let mut back = back;
    let id = back.live_ids()[0];
    back.delete_seq(id).unwrap();
    for t in back.trees() {
        t.arena.validate().unwrap();
    }
}

#[test]
fn churned_forest_matches_identically_churned_clone() {
    // Two forests fit identically and driven through the same operation
    // sequence must stay bit-exact tree by tree — arena allocation order is
    // a pure function of the op sequence, never of memory layout.
    let mut f1 = forest(260, 3, 0, 3);
    let mut f2 = forest(260, 3, 0, 3);
    let p = f1.data().n_features();
    let mut rng = Rng::new(13);
    for _ in 0..120 {
        if f1.n_alive() > 50 && rng.bernoulli(0.7) {
            let live = f1.live_ids();
            let id = live[rng.index(live.len())];
            f1.delete_seq(id).unwrap();
            f2.delete_seq(id).unwrap();
        } else {
            let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-3.0, 3.0)).collect();
            let y = rng.bernoulli(0.5) as u8;
            f1.add(&row, y);
            f2.add(&row, y);
        }
    }
    assert_eq!(f1.n_alive(), f2.n_alive());
    for (a, b) in f1.trees().iter().zip(f2.trees()) {
        assert!(a.structural_matches(b));
        // allocation determinism: identical op sequences produce identical
        // arena shapes, not just structural equality
        assert_eq!(a.arena.len(), b.arena.len());
        assert_eq!(a.arena.free_len(), b.arena.free_len());
    }
    let rows: Vec<Vec<f32>> = (0..60u32).map(|i| f1.data().row(i)).collect();
    assert_eq!(f1.predict_proba_rows(&rows), f2.predict_proba_rows(&rows));
}
