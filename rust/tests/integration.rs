//! Integration tests: cross-module flows — corpus → forest → deletion →
//! metrics, snapshots, the coordinator over TCP, the PJRT runtime, and the
//! experiment harness at smoke scale.

use dare::coordinator::{serve, Client, ServiceConfig, UnlearningService};
use dare::data::registry::find;
use dare::data::split::train_test;
use dare::eval::adversary::Adversary;
use dare::eval::speedup::{measure, SpeedupConfig};
use dare::forest::{serialize, structural_eq, DareForest, MaxFeatures, Params, SplitCriterion};
use dare::util::json::parse;
use dare::util::rng::Rng;

fn corpus_forest(name: &str, n_trees: usize, d_rmax: usize) -> (DareForest, dare::data::Dataset) {
    let info = find(name).unwrap();
    let data = info.generate(20_000, 5);
    let (train, test) = train_test(&data, 0.8, 5);
    let params = Params {
        n_trees,
        max_depth: 8,
        k: 10,
        d_rmax,
        n_threads: 2,
        ..Default::default()
    };
    (DareForest::fit(train, &params, 11), test)
}

#[test]
fn corpus_to_metrics_pipeline() {
    let (forest, test) = corpus_forest("twitter", 10, 2);
    let probs = forest.predict_proba_dataset(&test);
    let (_, ys, _) = test.to_row_major();
    let auc = dare::metrics::auc(&probs, &ys);
    assert!(auc > 0.6, "auc {auc}");
}

#[test]
fn unlearning_matches_scratch_model_distributionally() {
    // Delete 30% of training data; the unlearned model's test metric should
    // track a scratch-trained model on the reduced data closely.
    let info = find("synthetic").unwrap();
    let data = info.generate(2_000, 9);
    let (train, test) = train_test(&data, 0.8, 9);
    let (_, ys, _) = test.to_row_major();
    let params = Params {
        n_trees: 20,
        max_depth: 8,
        k: 10,
        n_threads: 2,
        ..Default::default()
    };
    let mut unlearned = DareForest::fit(train.clone(), &params, 21);
    let mut rng = Rng::new(3);
    let n_del = unlearned.n_alive() * 3 / 10;
    for _ in 0..n_del {
        let live = unlearned.live_ids();
        let id = live[rng.index(live.len())];
        unlearned.delete_seq(id).unwrap();
    }
    let reduced = unlearned.data().compacted();
    let scratch = DareForest::fit(reduced, &params, 22);
    let acc_unlearned =
        dare::metrics::accuracy(&unlearned.predict_proba_dataset(&test), &ys);
    let acc_scratch = dare::metrics::accuracy(&scratch.predict_proba_dataset(&test), &ys);
    assert!(
        (acc_unlearned - acc_scratch).abs() < 0.07,
        "unlearned {acc_unlearned} vs scratch {acc_scratch}"
    );
}

#[test]
fn full_exactness_forest_level() {
    // Forest-level version of the exhaustive-k structural-equality check.
    let info = find("ctr").unwrap();
    let data = info.generate(50_000, 2);
    let (train, _) = train_test(&data, 0.8, 2);
    let params = Params {
        n_trees: 3,
        max_depth: 5,
        k: 100_000,
        max_features: MaxFeatures::All,
        n_threads: 2,
        ..Default::default()
    };
    let mut f = DareForest::fit(train, &params, 77);
    for id in [3u32, 55, 200, 411] {
        f.delete(id).unwrap();
    }
    let scratch = DareForest::fit(f.data().compacted(), &params, 77);
    // note: scratch is trained on compacted ids, so compare predictions (ids
    // shift); structural comparison needs the same id space:
    // reuse the already-masked dataset: training only sees live ids, so the
    // id space matches for structural comparison
    let scratch_same_ids = DareForest::fit(f.data().clone(), &params, 77);
    for (a, b) in f.trees().iter().zip(scratch_same_ids.trees()) {
        assert!(a.structural_matches(b), "delete != scratch");
        assert!(
            structural_eq(&a.root_node(), &b.root_node()),
            "boxed views diverge"
        );
    }
    // prediction parity with the compacted scratch model too
    for i in 0..50u32 {
        let row = f.data().row(i);
        assert!((f.predict_proba(&row) - scratch.predict_proba(&row)).abs() < 1e-6);
    }
}

#[test]
fn snapshot_roundtrip_through_service() {
    let (forest, _) = corpus_forest("adult", 4, 1);
    let svc = UnlearningService::new(
        forest,
        ServiceConfig {
            use_pjrt: false,
            ..Default::default()
        },
    );
    svc.handle(&parse(r#"{"op":"delete","ids":[1,2,3]}"#).unwrap());
    let path = std::env::temp_dir().join("dare_integration_snapshot.json");
    let resp = svc.handle(
        &parse(&format!(r#"{{"op":"save","path":"{}"}}"#, path.display())).unwrap(),
    );
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    let restored = serialize::load(&path).unwrap();
    assert_eq!(restored.n_alive(), svc.sharded().n_alive());
    std::fs::remove_file(&path).ok();
}

#[test]
fn service_over_tcp_full_flow() {
    let (forest, test) = corpus_forest("bank_marketing", 5, 2);
    let svc = UnlearningService::new(
        forest,
        ServiceConfig {
            use_pjrt: false,
            ..Default::default()
        },
    );
    let svc2 = std::sync::Arc::clone(&svc);
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(svc2, "127.0.0.1:0", 2, move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut c = Client::connect(addr).unwrap();

    // typed client: predict a test row
    let row = test.row(0);
    let pred = c.predict("default", &[row.clone()]).unwrap();
    assert_eq!(pred.probs.len(), 1);
    assert!((0.0..=1.0).contains(&pred.probs[0]));

    // delete, add, cost, stats — all through the typed v1 surface
    let out = c.delete("default", &[7, 8]).unwrap();
    assert_eq!(out.deleted, 2);
    let id = c.add("default", &row, 1).unwrap();
    assert!(id as usize >= 7, "fresh id appended after the training set");
    let _cost = c.delete_cost("default", 20).unwrap();
    let r = c.stats("default").unwrap();
    assert!(r.get("telemetry").is_some());

    // typed errors cross the wire as their taxonomy variants
    assert!(matches!(
        c.delete_cost("default", 99_999_999),
        Err(dare::coordinator::ApiError::UnknownId(_))
    ));
    assert!(matches!(
        c.predict("nope", &[row]),
        Err(dare::coordinator::ApiError::UnknownModel(_))
    ));
    // and a raw v0 request is still served on the same connection
    let r = c.call(&parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));

    c.shutdown().unwrap();
    server.join().unwrap();
}

#[test]
fn speedup_pipeline_on_corpus_entry() {
    let info = find("credit_card").unwrap();
    let data = info.generate(20_000, 4);
    let (train, test) = train_test(&data, 0.8, 4);
    let params = Params {
        n_trees: 5,
        max_depth: 8,
        k: 5,
        n_threads: 2,
        ..Default::default()
    };
    let r = measure(
        &train,
        &test,
        &params,
        &SpeedupConfig {
            adversary: Adversary::Random,
            max_deletions: 25,
            metric: info.metric,
            seed: 6,
        },
    );
    assert!(r.speedup > 1.0, "speedup {}", r.speedup);
    assert!(r.metric_before >= 0.0 && r.metric_before <= 1.0);
}

#[test]
fn pjrt_runtime_agrees_with_forest_when_artifacts_present() {
    let Some(dir) = dare::runtime::manifest::locate_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = dare::runtime::Manifest::load(&dir).unwrap();
    let Ok(engine) = dare::runtime::Engine::global() else {
        eprintln!("skipping: PJRT backend unavailable");
        return;
    };
    let (forest, test) = corpus_forest("higgs", 6, 1);
    let predictor = dare::runtime::PjrtPredictor::new(engine, &manifest, &forest).unwrap();
    let rows: Vec<Vec<f32>> = test.live_ids().iter().take(40).map(|&i| test.row(i)).collect();
    let pjrt = predictor.predict(&rows).unwrap();
    for (i, row) in rows.iter().enumerate() {
        assert!((pjrt[i] - forest.predict_proba(row)).abs() < 1e-5);
    }
}

#[test]
fn entropy_criterion_full_cycle() {
    let info = find("twitter").unwrap();
    let data = info.generate(20_000, 8);
    let (train, test) = train_test(&data, 0.8, 8);
    let params = Params {
        n_trees: 5,
        max_depth: 7,
        k: 10,
        criterion: SplitCriterion::Entropy,
        n_threads: 2,
        ..Default::default()
    };
    let mut f = DareForest::fit(train, &params, 2);
    for id in f.live_ids().into_iter().take(30) {
        f.delete_seq(id).unwrap();
    }
    let probs = f.predict_proba_dataset(&test);
    let (_, ys, _) = test.to_row_major();
    assert!(dare::metrics::auc(&probs, &ys) > 0.55);
}

#[test]
fn experiment_smoke_fig1_table2() {
    // Tiny smoke of the full experiment pipeline: fig1 → table2 aggregation.
    let cfg = dare::exp::ExpConfig {
        scale_div: 50_000,
        repeats: 1,
        max_deletions: 5,
        worst_of: 5,
        datasets: vec!["twitter".into()],
        max_trees: 2,
        out_dir: std::env::temp_dir().join("dare_integration_exp"),
        ..Default::default()
    };
    let rows = dare::exp::table2::run(&cfg).unwrap();
    assert!(!rows.is_empty());
    // rerun reuses the cached fig1 json
    let rows2 = dare::exp::table2::run(&cfg).unwrap();
    assert_eq!(rows.len(), rows2.len());
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}
