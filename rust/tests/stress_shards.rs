//! ISSUE 3: concurrent churn stress — hammer the sharded service from
//! multiple client threads with mixed delete/add/predict/delete_cost and
//! audit the wreckage:
//!
//! - every shard's arenas pass `validate()` (no leaked/double-freed slots,
//!   planes in agreement);
//! - no instance is lost or duplicated across shards — every tree covers
//!   exactly the live id set (`ShardedForest::validate`);
//! - telemetry op counters sum to exactly the ops issued, and the bookkept
//!   live count matches `initial - deleted + added`.
//!
//! ≥ 1000 mixed ops (acceptance floor) across 6 threads, all through the
//! JSON `handle()` surface so the decode/dispatch/encode layers, batcher,
//! registry and telemetry are all in the loop. ISSUE 5 adds a second
//! registry tenant hammered concurrently over the v1 wire: its counters
//! reconcile per-model, and the default tenant's live count proves the
//! tenants never bleed into each other.

use dare::coordinator::{ServiceConfig, UnlearningService};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params};
use dare::util::json::{parse, Value};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

const N: usize = 600;
/// Second tenant's dataset size (its deleter uses ids 0..TENANT2_DELETES).
const N2: usize = 300;
const TENANT2_DELETES: usize = 100;
const OPS_PER_THREAD: usize = 200;

fn service() -> Arc<UnlearningService> {
    let d = generate(
        &SynthSpec {
            n: N,
            informative: 4,
            redundant: 1,
            noise: 3,
            flip: 0.05,
            ..Default::default()
        },
        11,
    );
    let f = DareForest::fit(
        d,
        &Params {
            n_trees: 8,
            max_depth: 6,
            k: 5,
            d_rmax: 1,
            ..Default::default()
        },
        23,
    );
    // a second tenant with a *different* arity, so any cross-tenant
    // misrouting of a data-plane op would fail loudly (arity_mismatch)
    let d2 = generate(
        &SynthSpec {
            n: N2,
            informative: 3,
            redundant: 0,
            noise: 1,
            flip: 0.05,
            ..Default::default()
        },
        31,
    );
    let f2 = DareForest::fit(
        d2,
        &Params {
            n_trees: 4,
            max_depth: 5,
            k: 5,
            ..Default::default()
        },
        37,
    );
    UnlearningService::with_models(
        vec![("default".to_string(), f), ("tenant2".to_string(), f2)],
        ServiceConfig {
            batch_window: Duration::from_millis(2),
            use_pjrt: false,
            n_shards: 4,
            ..Default::default()
        },
    )
}

#[test]
fn concurrent_churn_leaves_every_shard_consistent() {
    let svc = service();
    let p = svc.n_features();
    assert_eq!(svc.sharded().n_shards(), 4);

    // Issued-op counters, shared across client threads, keyed like telemetry.
    let issued_delete = Arc::new(AtomicU64::new(0));
    let issued_add = Arc::new(AtomicU64::new(0));
    let issued_predict = Arc::new(AtomicU64::new(0));
    let issued_cost = Arc::new(AtomicU64::new(0));
    let deleted_ok = Arc::new(AtomicU64::new(0));
    let added_ok = Arc::new(AtomicU64::new(0));

    let mut handles = Vec::new();
    // 2 deleter threads with disjoint id pools (every delete hits a live id
    // exactly once across the run — lost/duplicated deletions would show up
    // in the live-count reconciliation below).
    for c in 0..2u32 {
        let svc = Arc::clone(&svc);
        let issued = Arc::clone(&issued_delete);
        let ok = Arc::clone(&deleted_ok);
        handles.push(std::thread::spawn(move || {
            for r in 0..OPS_PER_THREAD as u32 {
                let id = c * OPS_PER_THREAD as u32 + r; // disjoint pools < N
                let req = parse(&format!(r#"{{"op":"delete","ids":[{id}]}}"#)).unwrap();
                let resp = svc.handle(&req);
                issued.fetch_add(1, Ordering::SeqCst);
                assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "delete {id}");
                ok.fetch_add(
                    resp.get("deleted").and_then(Value::as_u64).unwrap_or(0),
                    Ordering::SeqCst,
                );
            }
        }));
    }
    // 1 adder thread
    {
        let svc = Arc::clone(&svc);
        let issued = Arc::clone(&issued_add);
        let ok = Arc::clone(&added_ok);
        handles.push(std::thread::spawn(move || {
            for r in 0..OPS_PER_THREAD {
                let row: Vec<String> =
                    (0..p).map(|j| format!("{}", 0.01 * (r + j) as f32)).collect();
                let req = parse(&format!(
                    r#"{{"op":"add","row":[{}],"label":{}}}"#,
                    row.join(","),
                    r % 2
                ))
                .unwrap();
                let resp = svc.handle(&req);
                issued.fetch_add(1, Ordering::SeqCst);
                assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "add #{r}");
                ok.fetch_add(1, Ordering::SeqCst);
            }
        }));
    }
    // 2 predictor threads (read path must never observe a torn model)
    for c in 0..2u32 {
        let svc = Arc::clone(&svc);
        let issued = Arc::clone(&issued_predict);
        handles.push(std::thread::spawn(move || {
            for r in 0..OPS_PER_THREAD {
                let v = 0.05 * ((r as u32 + c * 7) % 40) as f32 - 1.0;
                let row = vec![format!("{v}"); p].join(",");
                let req =
                    parse(&format!(r#"{{"op":"predict","rows":[[{row}],[{row}]]}}"#)).unwrap();
                let resp = svc.handle(&req);
                issued.fetch_add(1, Ordering::SeqCst);
                assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true));
                let probs = resp.get("probs").unwrap().as_arr().unwrap();
                assert_eq!(probs.len(), 2);
                for pr in probs {
                    let pr = pr.as_f64().unwrap();
                    assert!((0.0..=1.0).contains(&pr), "torn probability {pr}");
                }
            }
        }));
    }
    // 1 delete_cost thread probing ids nobody deletes (pool ≥ 2·OPS_PER_THREAD)
    {
        let svc = Arc::clone(&svc);
        let issued = Arc::clone(&issued_cost);
        handles.push(std::thread::spawn(move || {
            for r in 0..OPS_PER_THREAD {
                let id = 2 * OPS_PER_THREAD + (r % (N - 2 * OPS_PER_THREAD));
                let req = parse(&format!(r#"{{"op":"delete_cost","id":{id}}}"#)).unwrap();
                let resp = svc.handle(&req);
                issued.fetch_add(1, Ordering::SeqCst);
                assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "cost {id}");
            }
        }));
    }
    // 1 second-tenant thread over the v1 wire: deletes its own disjoint id
    // pool and predicts at its own (different) arity, concurrently with
    // all the traffic above.
    {
        let svc = Arc::clone(&svc);
        let p2 = svc.registry().get("tenant2").unwrap().n_features();
        handles.push(std::thread::spawn(move || {
            for r in 0..OPS_PER_THREAD {
                if r % 2 == 0 {
                    let id = r / 2; // 0..TENANT2_DELETES, each live exactly once
                    let req = parse(&format!(
                        r#"{{"v":1,"model":"tenant2","op":"delete","ids":[{id}]}}"#
                    ))
                    .unwrap();
                    let resp = svc.handle(&req);
                    assert_eq!(
                        resp.get("ok").and_then(Value::as_bool),
                        Some(true),
                        "tenant2 delete {id}"
                    );
                    assert_eq!(resp.get("deleted").and_then(Value::as_u64), Some(1));
                } else {
                    let v = 0.04 * (r % 30) as f32 - 0.5;
                    let row = vec![format!("{v}"); p2].join(",");
                    let req = parse(&format!(
                        r#"{{"v":1,"model":"tenant2","op":"predict","rows":[[{row}]]}}"#
                    ))
                    .unwrap();
                    let resp = svc.handle(&req);
                    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true));
                }
            }
        }));
    }

    for h in handles {
        h.join().unwrap();
    }

    let total_issued = issued_delete.load(Ordering::SeqCst)
        + issued_add.load(Ordering::SeqCst)
        + issued_predict.load(Ordering::SeqCst)
        + issued_cost.load(Ordering::SeqCst);
    assert!(total_issued >= 1000, "stress floor: issued {total_issued} ops");

    // --- telemetry reconciliation: counters sum to the ops issued ----------
    let stats = svc.handle(&parse(r#"{"op":"stats"}"#).unwrap());
    let ops = stats.get("telemetry").unwrap().get("ops").unwrap();
    let count_of = |op: &str| -> u64 {
        ops.get(op)
            .map(|o| o.get("count").unwrap().as_u64().unwrap())
            .unwrap_or(0)
    };
    assert_eq!(count_of("delete"), issued_delete.load(Ordering::SeqCst));
    assert_eq!(count_of("add"), issued_add.load(Ordering::SeqCst));
    assert_eq!(count_of("predict"), issued_predict.load(Ordering::SeqCst));
    assert_eq!(count_of("delete_cost"), issued_cost.load(Ordering::SeqCst));
    for op in ["delete", "add", "predict", "delete_cost"] {
        let errs = ops.get(op).unwrap().get("errors").unwrap().as_u64().unwrap();
        assert_eq!(errs, 0, "{op} reported errors under stress");
    }
    let mutations = svc.telemetry().counter("mutations");
    assert_eq!(
        mutations,
        issued_delete.load(Ordering::SeqCst) + issued_add.load(Ordering::SeqCst)
    );

    // --- state reconciliation: no instance lost or duplicated --------------
    let deleted = deleted_ok.load(Ordering::SeqCst);
    let added = added_ok.load(Ordering::SeqCst);
    assert_eq!(deleted, 2 * OPS_PER_THREAD as u64, "disjoint pools: every delete lands");
    let expect_alive = N as u64 - deleted + added;
    assert_eq!(
        stats.get("n_alive").and_then(Value::as_u64),
        Some(expect_alive),
        "live count drifted"
    );

    // --- second tenant reconciliation: per-model telemetry counted its own
    // ops (and only its own), its live set shrank by exactly its deleter's
    // pool, and its store audits clean — while the default tenant's live
    // count above already proved tenant2's churn never reached it.
    let tenant2 = svc.registry().get("tenant2").unwrap();
    assert_eq!(tenant2.telemetry().op_count("delete"), TENANT2_DELETES as u64);
    assert_eq!(tenant2.telemetry().op_count("predict"), (OPS_PER_THREAD - TENANT2_DELETES) as u64);
    assert_eq!(tenant2.telemetry().op_errors("delete"), 0);
    assert_eq!(tenant2.telemetry().op_errors("predict"), 0);
    assert_eq!(tenant2.telemetry().counter("mutations"), TENANT2_DELETES as u64);
    assert_eq!(tenant2.sharded().n_alive(), N2 - TENANT2_DELETES);
    tenant2.sharded().validate().unwrap();

    // --- structural audit: every shard validate()-clean, every tree covers
    // exactly the live id set (ShardedForest::validate checks both).
    svc.sharded().validate().unwrap();

    // every shard mutated at least once and a quiesced store reads even
    // epochs (seqlock). Exact per-shard agreement (+2 per mutation) holds
    // only under eager mode: under a lazy policy (the DARE_LAZY_POLICY
    // matrix leg) flush-on-read and the compactor legitimately add +2
    // bumps to exactly the shards they retrained, so epochs may diverge
    // upward — but never below the mutation count and never odd.
    let epochs = svc.sharded().shard_epochs();
    assert!(epochs.iter().all(|&e| e > 0), "epochs {epochs:?}");
    assert!(
        epochs.iter().all(|&e| e % 2 == 0),
        "store must be epoch-stable after quiescence: {epochs:?}"
    );
    if svc.lazy_policy().is_lazy() {
        assert!(
            epochs.iter().all(|&e| e >= 2 * mutations),
            "lazy epochs can only add flush bumps on top of mutations: {epochs:?}"
        );
    } else {
        assert!(
            epochs.iter().all(|&e| e == 2 * mutations),
            "per-shard epoch must count mutations: {epochs:?}"
        );
    }
}
