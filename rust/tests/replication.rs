//! ISSUE 7: log-shipping replication (DESIGN.md §12).
//!
//! DaRE replay is deterministic, so these tests can demand the strongest
//! possible property: a follower that has tailed the leader's WAL through
//! epoch E is **byte-identical** to the leader at E — the same serialized
//! forest JSON, the same predictions, and (both journals starting from
//! base epoch 0) the same `wal.log` bytes, because the wire codec that
//! frames shipped records is the codec both journals append with.
//!
//! The in-process tests run real TCP leaders and drive the follower's
//! catch-up loop deterministically (`spawn_tailers: false` +
//! `sync_once`). The end-to-end test (`#[ignore]`, CI runs it with
//! `DARE_BIN`) SIGKILLs a real leader binary mid-replication and promotes
//! the follower binary in its place.

use dare::coordinator::api::Op;
use dare::coordinator::wal::{dir_name, LogRecord, Wal, LOG_FILE};
use dare::coordinator::{
    bootstrap_follower, Applied, ApiError, Client, ReplicaState, ReplicationConfig, Request,
    ServiceConfig, UnlearningService, DEFAULT_MODEL,
};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::serialize::forest_to_json;
use dare::forest::{DareForest, Params};
use dare::util::json::parse;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

const KEY: &str = "replication-test-key";

fn temp_root(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dare-repl-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn fit_forest(seed: u64) -> DareForest {
    let d = generate(
        &SynthSpec {
            n: 120,
            informative: 3,
            redundant: 0,
            noise: 2,
            flip: 0.05,
            ..Default::default()
        },
        seed,
    );
    DareForest::fit(
        d,
        &Params {
            n_trees: 3,
            max_depth: 5,
            k: 5,
            ..Default::default()
        },
        seed ^ 0x51,
    )
}

/// A durable service config rooted at `wal_dir`. `snapshot_every: 0`
/// keeps every record addressable so raw `wal.log` comparisons hold.
fn durable_cfg(wal_dir: &Path) -> ServiceConfig {
    ServiceConfig {
        batch_window: Duration::from_millis(1),
        use_pjrt: false,
        n_shards: 2,
        wal_dir: Some(wal_dir.to_path_buf()),
        wal_snapshot_every: 0,
        cert_key: Some(KEY.to_string()),
        ..Default::default()
    }
}

fn spawn_service(svc: Arc<UnlearningService>) -> (SocketAddr, JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve_quiet(svc, tx);
    });
    (rx.recv().unwrap(), handle)
}

fn serve_quiet(svc: Arc<UnlearningService>, tx: std::sync::mpsc::Sender<SocketAddr>) {
    dare::coordinator::serve(svc, "127.0.0.1:0", 2, move |addr| {
        tx.send(addr).unwrap();
    })
    .unwrap();
}

/// Replication config for test-driven catch-up: no background tailers,
/// fast failure when the leader is down.
fn rcfg(leader: SocketAddr) -> ReplicationConfig {
    let mut cfg = ReplicationConfig {
        leader: leader.to_string(),
        spawn_tailers: false,
        ..Default::default()
    };
    cfg.client.connect_timeout = Duration::from_millis(500);
    cfg.client.io_timeout = Duration::from_millis(2000);
    cfg.client.retries = 0;
    cfg.client.backoff = Duration::from_millis(1);
    cfg
}

fn log_bytes(root: &Path, model: &str) -> Vec<u8> {
    std::fs::read(root.join(dir_name(model)).join(LOG_FILE)).unwrap()
}

fn model_json(svc: &Arc<UnlearningService>, name: &str) -> String {
    forest_to_json(&svc.registry().get(name).unwrap().snapshot_forest())
}

/// Run `n_ops` deterministic mutations against the leader over the wire.
fn mutate(c: &mut Client, p: usize, first_id: u32, n_ops: u32) {
    for i in 0..n_ops {
        if i % 3 == 2 {
            c.add("default", &vec![0.1 * f32::from((i % 7) as u8); p], (i % 2) as u8).unwrap();
        } else {
            c.delete("default", &[first_id + i]).unwrap();
        }
    }
}

#[test]
fn follower_bootstraps_tails_and_converges_byte_for_byte() {
    let leader_root = temp_root("happy-leader");
    let follower_root = temp_root("happy-follower");

    let leader = UnlearningService::with_models(
        vec![(DEFAULT_MODEL.to_string(), fit_forest(7))],
        durable_cfg(&leader_root),
    );
    let leader2 = Arc::clone(&leader);
    let (addr, handle) = spawn_service(leader);

    // Bootstrap the follower before any mutation, so both journals start
    // at base epoch 0 and the raw log files must converge byte-for-byte.
    let fsvc = UnlearningService::with_models(Vec::new(), durable_cfg(&follower_root));
    let cfg = rcfg(addr);
    let followed = bootstrap_follower(&fsvc, &cfg).unwrap();
    assert_eq!(followed, vec![DEFAULT_MODEL.to_string()]);
    let fmodel = fsvc.registry().get(DEFAULT_MODEL).unwrap();
    let rep = fmodel.replica().expect("bootstrap attaches replication state");
    assert_eq!(rep.sync_once(&fmodel).unwrap(), 0, "fresh follower is caught up");
    assert_eq!(model_json(&fsvc, DEFAULT_MODEL), model_json(&leader2, DEFAULT_MODEL));

    // Mutate the leader, tail, and demand exact convergence.
    let mut c = Client::connect(addr).unwrap();
    let p = fmodel.sharded().n_features();
    mutate(&mut c, p, 0, 7);
    let cert = c.certify("default", 0).unwrap();

    let mut applied = 0;
    loop {
        let n = rep.sync_once(&fmodel).unwrap();
        if n == 0 {
            break;
        }
        applied += n;
    }
    assert_eq!(applied, 7);
    assert_eq!(rep.applied_epoch(), 7);
    assert_eq!(rep.lag_epochs(), 0);
    assert!(rep.leader_reachable());

    let leader_json = model_json(&leader2, DEFAULT_MODEL);
    assert_eq!(model_json(&fsvc, DEFAULT_MODEL), leader_json, "forest JSON diverged");
    assert_eq!(
        log_bytes(&follower_root, DEFAULT_MODEL),
        log_bytes(&leader_root, DEFAULT_MODEL),
        "journals diverged"
    );

    // Predictions served by the follower equal the leader's, unannotated.
    let probe = format!(
        r#"{{"op":"predict","rows":[[{}]]}}"#,
        vec!["0.2"; p].join(",")
    );
    let fr = fsvc.handle(&parse(&probe).unwrap());
    let lr = leader2.handle(&parse(&probe).unwrap());
    assert_eq!(fr.to_string(), lr.to_string());
    assert!(fr.get("stale").is_none());

    // A certificate minted on the leader verifies on the follower (same
    // HMAC key; verification is model-independent).
    let verify = format!(
        r#"{{"v":1,"model":"default","op":"verify_cert","cert":{}}}"#,
        cert.to_wire()
    );
    let vr = fsvc.handle(&parse(&verify).unwrap());
    assert_eq!(vr.get("valid").map(|v| v.as_bool()), Some(Some(true)));

    // Follower restart: recovery comes from the *local* journal; the
    // resumed tail starts exactly where the journal ends.
    drop(rep);
    drop(fmodel);
    drop(fsvc);
    let fsvc = UnlearningService::with_models(Vec::new(), durable_cfg(&follower_root));
    assert_eq!(bootstrap_follower(&fsvc, &cfg).unwrap(), vec![DEFAULT_MODEL.to_string()]);
    let fmodel = fsvc.registry().get(DEFAULT_MODEL).unwrap();
    let rep = fmodel.replica().unwrap();
    assert_eq!(rep.applied_epoch(), 7, "restart must resume from the local journal");
    mutate(&mut c, p, 40, 2);
    while rep.sync_once(&fmodel).unwrap() > 0 {}
    assert_eq!(model_json(&fsvc, DEFAULT_MODEL), model_json(&leader2, DEFAULT_MODEL));

    c.shutdown().unwrap();
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&leader_root);
    let _ = std::fs::remove_dir_all(&follower_root);
}

#[test]
fn leader_crash_marks_unreachable_then_reconnect_converges() {
    let leader_root = temp_root("crash-leader");
    let follower_root = temp_root("crash-follower");

    let leader = UnlearningService::with_models(
        vec![(DEFAULT_MODEL.to_string(), fit_forest(9))],
        durable_cfg(&leader_root),
    );
    let leader2 = Arc::clone(&leader);
    let (addr, handle) = spawn_service(leader);

    let fsvc = UnlearningService::with_models(Vec::new(), durable_cfg(&follower_root));
    let cfg = rcfg(addr);
    bootstrap_follower(&fsvc, &cfg).unwrap();
    let fmodel = fsvc.registry().get(DEFAULT_MODEL).unwrap();
    let rep = fmodel.replica().unwrap();

    let mut c = Client::connect(addr).unwrap();
    let p = fmodel.sharded().n_features();
    mutate(&mut c, p, 0, 6);

    // Pull only part of the backlog (one record per round), then lose the
    // leader mid-catch-up.
    let mut one = cfg.clone();
    one.max_records = 1;
    let rep1 = ReplicaState::new(one, rep.applied_epoch());
    assert_eq!(rep1.sync_once(&fmodel).unwrap(), 1);
    fmodel.attach_replica(Arc::clone(&rep1));
    assert_eq!(rep1.applied_epoch(), 1);
    assert_eq!(rep1.lag_epochs(), 5, "pull_log must report the leader epoch");

    let leader_json = model_json(&leader2, DEFAULT_MODEL);
    c.shutdown().unwrap();
    handle.join().unwrap();
    drop(leader2);

    // Leader gone: catch-up fails, reachability flips, reads still serve.
    assert!(rep1.sync_once(&fmodel).is_err());
    assert!(!rep1.leader_reachable());
    let probe = format!(
        r#"{{"op":"predict","rows":[[{}]]}}"#,
        vec!["0.4"; p].join(",")
    );
    let r = fsvc.handle(&parse(&probe).unwrap());
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));

    // Restart the leader from its own journal at a new address, re-point
    // the follower, and demand byte-identical convergence.
    let leader = UnlearningService::with_models(Vec::new(), durable_cfg(&leader_root));
    assert_eq!(model_json(&leader, DEFAULT_MODEL), leader_json, "leader recovery diverged");
    let leader2 = Arc::clone(&leader);
    let (addr2, handle2) = spawn_service(leader);
    rep1.set_leader(&addr2.to_string());
    while rep1.sync_once(&fmodel).unwrap() > 0 {}
    assert!(rep1.leader_reachable());
    assert_eq!(rep1.lag_epochs(), 0);
    assert_eq!(model_json(&fsvc, DEFAULT_MODEL), leader_json);
    assert_eq!(
        log_bytes(&follower_root, DEFAULT_MODEL),
        log_bytes(&leader_root, DEFAULT_MODEL)
    );

    Client::connect(addr2).unwrap().shutdown().unwrap();
    handle2.join().unwrap();
    drop(leader2);
    let _ = std::fs::remove_dir_all(&leader_root);
    let _ = std::fs::remove_dir_all(&follower_root);
}

#[test]
fn shipped_faults_are_rejected_without_corrupting_the_local_journal() {
    let follower_root = temp_root("faults");
    let fsvc = UnlearningService::with_models(Vec::new(), durable_cfg(&follower_root));

    // Install a follower model directly from a snapshot at epoch 0 and
    // drive `apply_shipped` by hand — the unit under test is the
    // epoch-chain rule, independent of any transport.
    let base = fit_forest(3);
    let snapshot = forest_to_json(&base);
    let fmodel = fsvc.install_snapshot(DEFAULT_MODEL, &snapshot, 0).unwrap();
    let rep = ReplicaState::new(
        ReplicationConfig {
            leader: "127.0.0.1:1".to_string(),
            spawn_tailers: false,
            ..Default::default()
        },
        0,
    );
    fmodel.attach_replica(Arc::clone(&rep));

    let shipped = |epoch: u64, op: Op| LogRecord {
        epoch,
        request: Request {
            v: 1,
            model: DEFAULT_MODEL.to_string(),
            op,
        },
    };

    // Valid successor applies.
    assert_eq!(
        rep.apply_shipped(&fmodel, &shipped(1, Op::Delete { ids: vec![5] })).unwrap(),
        Applied::Ok
    );
    let after_one = model_json(&fsvc, DEFAULT_MODEL);
    let log_after_one = log_bytes(&follower_root, DEFAULT_MODEL);

    // Duplicate / stale epochs dedup silently (reconnect overlap).
    for epoch in [0, 1] {
        assert_eq!(
            rep.apply_shipped(&fmodel, &shipped(epoch, Op::Delete { ids: vec![9] })).unwrap(),
            Applied::Duplicate,
            "epoch {epoch} must dedup"
        );
    }
    // A gap is refused, naming the epochs.
    let err = rep
        .apply_shipped(&fmodel, &shipped(3, Op::Delete { ids: vec![9] }))
        .unwrap_err()
        .to_string();
    assert!(err.contains("epoch gap"), "{err}");
    // Wrong-model and non-mutating records are refused.
    let mut wrong = shipped(2, Op::Delete { ids: vec![9] });
    wrong.request.model = "other".to_string();
    assert!(rep.apply_shipped(&fmodel, &wrong).is_err());
    assert!(rep
        .apply_shipped(&fmodel, &shipped(2, Op::Stats))
        .unwrap_err()
        .to_string()
        .contains("non-mutating"));
    // An arity-mismatched add is refused before it can touch the store.
    assert!(rep.apply_shipped(&fmodel, &shipped(2, Op::Add { row: vec![0.5], label: 1 })).is_err());

    // None of the rejected records touched live state or the journal...
    assert_eq!(rep.applied_epoch(), 1);
    assert_eq!(model_json(&fsvc, DEFAULT_MODEL), after_one);
    assert_eq!(log_bytes(&follower_root, DEFAULT_MODEL), log_after_one);
    // ...and the journal still recovers to exactly the live state.
    let rec = Wal::recover(
        &follower_root,
        &dir_name(DEFAULT_MODEL),
        dare::coordinator::FsyncPolicy::EveryOp,
        0,
        KEY.as_bytes().to_vec(),
    )
    .unwrap();
    assert_eq!(forest_to_json(&rec.forest), after_one);
    assert_eq!(rec.wal.epoch(), 1);

    // A leader that answers garbage is a transport error, not corruption:
    // the catch-up round fails, the journal stays intact.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let garbage_addr = listener.local_addr().unwrap();
    let garbler = std::thread::spawn(move || {
        use std::io::{BufRead, BufReader, Write};
        let (mut s, _) = listener.accept().unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        s.write_all(b"{{{ not json\n").unwrap();
    });
    let rep2 = ReplicaState::new(
        {
            let mut cfg = rcfg(garbage_addr);
            cfg.leader = garbage_addr.to_string();
            cfg
        },
        rep.applied_epoch(),
    );
    assert!(rep2.sync_once(&fmodel).is_err());
    assert!(!rep2.leader_reachable());
    garbler.join().unwrap();
    assert_eq!(log_bytes(&follower_root, DEFAULT_MODEL), log_after_one);

    let _ = std::fs::remove_dir_all(&follower_root);
}

#[test]
fn promote_under_lag_drains_fully_then_accepts_writes() {
    let leader_root = temp_root("promote-leader");
    let follower_root = temp_root("promote-follower");

    let leader = UnlearningService::with_models(
        vec![(DEFAULT_MODEL.to_string(), fit_forest(21))],
        durable_cfg(&leader_root),
    );
    let leader2 = Arc::clone(&leader);
    let (addr, handle) = spawn_service(leader);

    let fsvc = UnlearningService::with_models(Vec::new(), durable_cfg(&follower_root));
    let mut cfg = rcfg(addr);
    cfg.max_records = 2; // several drain rounds under lag
    bootstrap_follower(&fsvc, &cfg).unwrap();
    let fmodel = fsvc.registry().get(DEFAULT_MODEL).unwrap();

    // Build up lag the follower has not seen at all.
    let mut c = Client::connect(addr).unwrap();
    let p = fmodel.sharded().n_features();
    mutate(&mut c, p, 0, 9);
    let leader_json = model_json(&leader2, DEFAULT_MODEL);

    // Promote while 9 epochs behind: the drain must pull everything
    // before flipping roles.
    let r = fsvc.handle(&parse(r#"{"op":"promote"}"#).unwrap());
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(r.get("epoch").unwrap().as_u64(), Some(9));
    assert_eq!(model_json(&fsvc, DEFAULT_MODEL), leader_json, "promote drained partially");
    assert!(!fmodel.is_follower());

    // The promoted model accepts writes and journals them on the same
    // epoch chain...
    let w = fsvc.handle(&parse(r#"{"op":"delete","ids":[30]}"#).unwrap());
    assert_eq!(w.get("ok").unwrap().as_bool(), Some(true), "{w}");
    let s = fsvc.handle(&parse(r#"{"op":"stats"}"#).unwrap());
    assert_eq!(s.get("role").unwrap().as_str(), Some("leader"));
    assert_eq!(s.get("wal_epoch").unwrap().as_u64(), Some(10));

    // ...and its journal replays cleanly: recovery equals the live state.
    let promoted_json = model_json(&fsvc, DEFAULT_MODEL);
    drop(fmodel);
    drop(fsvc);
    let recovered = UnlearningService::with_models(Vec::new(), durable_cfg(&follower_root));
    assert_eq!(model_json(&recovered, DEFAULT_MODEL), promoted_json);

    // Serving a mutation on the *old* leader afterward is fine (split
    // brain is the operator's to avoid; this repo ships promotion, not
    // consensus) — but the old leader's state is now behind the new one.
    c.shutdown().unwrap();
    handle.join().unwrap();
    drop(leader2);
    let _ = std::fs::remove_dir_all(&leader_root);
    let _ = std::fs::remove_dir_all(&follower_root);
}

/// End-to-end failover against real binaries; CI runs this as
///
///   DARE_BIN=target/release/dare cargo test --release --test replication -- --ignored
#[test]
#[ignore = "needs a built binary via DARE_BIN"]
fn sigkill_leader_then_promoted_follower_serves_identical_predictions() {
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};

    let Ok(bin) = std::env::var("DARE_BIN") else {
        eprintln!("replication: DARE_BIN not set; skipping");
        return;
    };
    let root = temp_root("e2e");
    let model_path = root.join("model.json");
    let status = Command::new(&bin)
        .args([
            "train", "--dataset", "surgical", "--scale", "2000", "--trees", "3", "--depth", "5",
            "--save", model_path.to_str().unwrap(),
        ])
        .status()
        .expect("run train");
    assert!(status.success(), "train failed");

    let spawn = |extra: &[&str]| {
        let mut child = Command::new(&bin)
            .args(["serve", "--addr", "127.0.0.1:0", "--workers", "2", "--fsync", "every_op",
                   "--hmac-key", KEY])
            .args(extra)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn server");
        let stdout = child.stdout.take().unwrap();
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            let line = lines.next().expect("server exited before binding").expect("read stdout");
            if let Some(rest) = line.strip_prefix("listening on ") {
                break rest.split_whitespace().next().unwrap().to_string();
            }
        };
        std::thread::spawn(move || for _ in lines {});
        (child, addr)
    };

    let leader_wal = root.join("leader-wal");
    let follower_wal = root.join("follower-wal");
    let (mut leader, laddr) = spawn(&[
        "--load", model_path.to_str().unwrap(),
        "--wal-dir", leader_wal.to_str().unwrap(),
    ]);
    let mut lc = Client::connect(laddr.as_str()).expect("connect leader");
    let p = lc.stats("default").unwrap().get("n_features").unwrap().as_u64().unwrap() as usize;
    lc.delete("default", &[0, 3, 8]).unwrap();
    lc.add("default", &vec![0.4; p], 1).unwrap();
    let cert = lc.certify("default", 3).unwrap();

    let (mut follower, faddr) = spawn(&[
        "--follow", &laddr,
        "--wal-dir", follower_wal.to_str().unwrap(),
        "--poll-ms", "20",
    ]);
    let mut fc = Client::connect(faddr.as_str()).expect("connect follower");

    // More writes land while the follower tails; wait for lag 0.
    lc.delete("default", &[11, 12]).unwrap();
    let probe = vec![vec![0.1_f32; p]];
    let expected = lc.predict("default", &probe).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    loop {
        let s = fc.stats("default").unwrap();
        assert_eq!(s.get("role").unwrap().as_str(), Some("follower"));
        if s.get("replication_lag_epochs").unwrap().as_u64() == Some(0)
            && s.get("wal_epoch").unwrap().as_u64() == Some(3)
        {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "follower never caught up: {s}");
        std::thread::sleep(Duration::from_millis(50));
    }

    // Mutations bounce off the follower with the read_only wire code.
    match fc.delete("default", &[20]) {
        Err(ApiError::ReadOnly { leader }) => assert_eq!(leader, laddr),
        other => panic!("follower accepted a mutation: {other:?}"),
    }

    // SIGKILL the leader — no flush, no goodbye — then fail over.
    leader.kill().expect("SIGKILL leader");
    leader.wait().unwrap();
    let epoch = fc.promote("default").expect("promote");
    assert_eq!(epoch, 3);
    assert_eq!(fc.predict("default", &probe).unwrap(), expected);
    assert!(fc.verify_cert(&cert).unwrap(), "leader-minted certificate rejected");
    fc.delete("default", &[20]).expect("promoted follower must accept writes");
    assert_eq!(fc.stats("default").unwrap().get("role").unwrap().as_str(), Some("leader"));

    fc.shutdown().unwrap();
    follower.wait().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}
