//! ISSUE 3: op-sequence differential fuzzing — the paper's exactness
//! guarantee as an executable property over *randomized interleavings* of
//! `add` / `delete` / `delete_cost` / `predict`, instead of a handful of
//! fixed grids.
//!
//! Three legs:
//!
//! 1. **Four-way differential** (≥ 20 seeds, env-overridable): every op is
//!    applied through (a) the boxed oracle path (`forest::delete` over
//!    `Node` trees, per-tree seeds/epochs replicated from `DareForest`),
//!    (b) the arena path (`DareForest`), (c) the sharded coordinator
//!    store (`coordinator::shards::ShardedForest`), and (d) a **lazy**
//!    `DareForest` (`LazyPolicy::OnRead` or `Budgeted`, per seed). After
//!    every mutation legs (a)–(c) must agree bit-exactly: tree structures,
//!    `DeleteReport`s, deletion-cost dry runs, live counts, and predicted
//!    probabilities (f32 `==`, not tolerances). The lazy leg must agree on
//!    every *served* value (reports, as-if-flushed costs, flush-on-read
//!    predictions) at the moment of the query, and on full structure +
//!    serialized bytes whenever its dirty set drains — the fuzz alphabet
//!    includes explicit `flush` / `compact` ops to exercise exactly that.
//! 2. **Scratch-retrain exactness** (the paper's theorem): in the
//!    exhaustive regime (k ≥ all candidates, all attributes, no random
//!    layer — where threshold *sampling* is degenerate and the theorem is
//!    a structural identity rather than a distributional one), every
//!    deletion must leave each tree `structural_eq` to a from-scratch
//!    retrain on the surviving instances. Additions are exercised in leg 1
//!    only: the §6 add path resamples thresholds only on broken adjacency,
//!    so a new extreme value can introduce a candidate scratch training
//!    would also see — additions are *oracle-exact* (boxed reference), not
//!    scratch-exact, and the paper's unlearning theorem covers deletion.
//!
//! 3. **Registry differential** (ISSUE 5): two tenants behind one
//!    `UnlearningService`, driven through the versioned wire surface in
//!    lockstep with standalone `ShardedForest` oracles — responses
//!    byte-identical, tenants fully isolated (see the test's doc comment).
//!
//! Seeds come from `DARE_FUZZ_SEEDS` (comma-separated) when set — CI pins a
//! fixed list — else a built-in 22-seed default. No external fuzzing deps:
//! seeded `util::rng` streams, same style as `proptests.rs`.
//!
//! A fourth leg (ISSUE 9) fuzzes at the *scenario* layer: randomized
//! `exp::scenarios` specs (multi-tenant scripts over the full op
//! vocabulary, adversarial or random delete targets, Occ(q) tenants) are
//! compiled once and replayed twice through the coordinator stack — the
//! replays must agree byte-for-byte on final forest state and
//! count-for-count on per-op histograms, and the first replay must pass
//! the harness's full oracle cross-check, under the ambient
//! `DARE_LAZY_POLICY`.

use dare::coordinator::api::{encode_response, Response};
use dare::coordinator::{ServiceConfig, ShardedForest, UnlearningService};
use dare::data::dataset::Dataset;
use dare::forest::delete as boxed;
use dare::forest::delete::DeleteReport;
use dare::forest::forest::tree_seed;
use dare::forest::serialize::forest_to_json;
use dare::forest::train::{train, TrainCtx, ROOT_PATH};
use dare::forest::{owned_live_ids, owns, DareForest, LazyPolicy, MaxFeatures, Node, Params};
use dare::util::prop::{gen_feature_column, gen_labels};
use dare::util::rng::{mix_seed, Rng};

fn random_dataset(rng: &mut Rng, n: usize, p: usize) -> Dataset {
    let cols: Vec<Vec<f32>> = (0..p)
        .map(|_| gen_feature_column(rng, n, 0.3, 4.0))
        .collect();
    let labels = gen_labels(rng, n, 0.25 + 0.5 * rng.f64());
    Dataset::from_columns(cols, labels)
}

fn assert_reports_eq(a: &DeleteReport, b: &DeleteReport, what: &str) {
    assert_eq!(a.retrain_events, b.retrain_events, "{what}: retrain events diverged");
    assert_eq!(
        a.thresholds_resampled, b.thresholds_resampled,
        "{what}: threshold resample count diverged"
    );
    assert_eq!(a.attrs_resampled, b.attrs_resampled, "{what}: attr resample count diverged");
}

/// The three implementations under test, driven in lockstep.
struct Harness {
    params: Params,
    tree_seeds: Vec<u64>,
    /// (a) boxed oracle: its own dataset copy + per-tree epochs, exactly
    /// replicating what `DareTree::delete`/`add` feed the reference path.
    boxed_data: Dataset,
    boxed_trees: Vec<Node>,
    epochs: Vec<u64>,
    /// (b) the arena path.
    arena: DareForest,
    /// (c) the sharded coordinator store.
    sharded: ShardedForest,
    /// (d) the deferred pipeline (DESIGN.md §9): marks on mutation,
    /// flushes on read / explicit flush ops.
    lazy: DareForest,
}

impl Harness {
    fn new(
        data: Dataset,
        params: Params,
        forest_seed: u64,
        n_shards: usize,
        policy: LazyPolicy,
    ) -> Harness {
        let tree_seeds: Vec<u64> = (0..params.n_trees)
            .map(|t| tree_seed(forest_seed, t))
            .collect();
        let boxed_trees: Vec<Node> = tree_seeds
            .iter()
            .map(|&ts| {
                let ctx = TrainCtx {
                    data: &data,
                    params: &params,
                    tree_seed: ts,
                };
                // Occ(q): each oracle trains from scratch on exactly its
                // owned ids (the full live set at q=1.0 — `owned_live_ids`
                // is the identity there, preserving the original leg).
                train(&ctx, owned_live_ids(&data, ts, params.q), 0, ROOT_PATH)
            })
            .collect();
        let arena = DareForest::fit(data.clone(), &params, forest_seed);
        let sharded =
            ShardedForest::new(DareForest::fit(data.clone(), &params, forest_seed), n_shards);
        let mut lazy = DareForest::fit(data.clone(), &params, forest_seed);
        lazy.set_lazy_policy(policy);
        let epochs = vec![0u64; boxed_trees.len()];
        Harness {
            params,
            tree_seeds,
            boxed_data: data,
            boxed_trees,
            epochs,
            arena,
            sharded,
            lazy,
        }
    }

    fn n_alive(&self) -> usize {
        self.boxed_data.n_alive()
    }

    /// All three eager tree sets must be structurally identical, the live
    /// counts must agree everywhere, and the lazy leg must stay internally
    /// consistent (arena + dirty-set audit). The lazy leg's *structure* is
    /// asserted only when its dirty set is empty — mid-deferral its pending
    /// leaves intentionally differ from the eager trees.
    fn check_structure(&self, when: &str) {
        assert_eq!(self.arena.n_alive(), self.boxed_data.n_alive(), "{when}: arena n_alive");
        assert_eq!(self.sharded.n_alive(), self.boxed_data.n_alive(), "{when}: sharded n_alive");
        assert_eq!(self.lazy.n_alive(), self.boxed_data.n_alive(), "{when}: lazy n_alive");
        for (t, node) in self.boxed_trees.iter().enumerate() {
            assert!(
                self.arena.trees()[t].matches_root(node),
                "{when}: arena tree {t} diverged from the boxed oracle"
            );
        }
        self.sharded.for_each_tree(|gt, tree| {
            assert!(
                tree.structural_matches(&self.arena.trees()[gt]),
                "{when}: sharded tree {gt} diverged from the arena path"
            );
        });
        for (t, tree) in self.lazy.trees().iter().enumerate() {
            tree.validate()
                .unwrap_or_else(|e| panic!("{when}: lazy tree {t} inconsistent: {e}"));
        }
        if self.lazy.dirty_subtrees() == 0 {
            self.check_lazy_flushed(when);
        }
    }

    /// With an empty dirty set the lazy leg must be bit-identical to the
    /// eager path: structure AND serialized bytes.
    fn check_lazy_flushed(&self, when: &str) {
        for (t, node) in self.boxed_trees.iter().enumerate() {
            assert!(
                self.lazy.trees()[t].matches_root(node),
                "{when}: flushed lazy tree {t} diverged from the boxed oracle"
            );
        }
        assert_eq!(
            forest_to_json(&self.lazy),
            forest_to_json(&self.arena),
            "{when}: flushed lazy forest serialized differently from the eager path"
        );
    }

    fn delete(&mut self, id: u32) {
        // (a) boxed oracle
        let mut boxed_reports = Vec::with_capacity(self.boxed_trees.len());
        for t in 0..self.boxed_trees.len() {
            // Occ(q): a non-owning oracle never sees the op — and,
            // critically, does not advance its epoch, exactly like the
            // gated production paths, so the Lemma-A.1 RNG streams of all
            // later owned deletions stay aligned.
            if !owns(self.tree_seeds[t], id, self.params.q) {
                boxed_reports.push(DeleteReport::default());
                continue;
            }
            let ctx = TrainCtx {
                data: &self.boxed_data,
                params: &self.params,
                tree_seed: self.tree_seeds[t],
            };
            let mut r = DeleteReport::default();
            boxed::delete(&ctx, &mut self.boxed_trees[t], id, 0, ROOT_PATH, self.epochs[t], &mut r);
            self.epochs[t] += 1;
            boxed_reports.push(r);
        }
        self.boxed_data.mark_removed(id);
        // (b) arena
        let ra = self.arena.delete_seq(id).unwrap();
        // (c) sharded (a single-id batch is one deletion)
        let (rs, skipped) = self.sharded.delete_batch(&[id]);
        // (d) lazy: the mark phase must report the identical retrain
        // events/costs even though the retrains themselves are deferred.
        let rl = self.lazy.delete_seq(id).unwrap();
        assert_eq!(skipped, 0, "live id must not be skipped");
        assert_eq!(ra.per_tree.len(), boxed_reports.len());
        assert_eq!(rs.per_tree.len(), boxed_reports.len());
        assert_eq!(rl.per_tree.len(), boxed_reports.len());
        for (t, rb) in boxed_reports.iter().enumerate() {
            assert_reports_eq(rb, &ra.per_tree[t], &format!("delete {id}, tree {t} (arena)"));
            assert_reports_eq(rb, &rs.per_tree[t], &format!("delete {id}, tree {t} (sharded)"));
            assert_reports_eq(rb, &rl.per_tree[t], &format!("delete {id}, tree {t} (lazy)"));
        }
        self.check_structure(&format!("after delete {id}"));
    }

    fn add(&mut self, row: &[f32], label: u8) {
        // (a) boxed oracle
        let id = self.boxed_data.push_row(row, label);
        for t in 0..self.boxed_trees.len() {
            // Occ(q): the instance joins each oracle with probability q —
            // the same stateless predicate the production add paths gate on.
            if !owns(self.tree_seeds[t], id, self.params.q) {
                continue;
            }
            let ctx = TrainCtx {
                data: &self.boxed_data,
                params: &self.params,
                tree_seed: self.tree_seeds[t],
            };
            let mut r = DeleteReport::default();
            boxed::add(&ctx, &mut self.boxed_trees[t], id, 0, ROOT_PATH, self.epochs[t], &mut r);
            self.epochs[t] += 1;
        }
        // (b) arena, (c) sharded, (d) lazy
        let id_a = self.arena.add(row, label);
        let id_s = self.sharded.add(row, label).unwrap();
        let id_l = self.lazy.add(row, label);
        assert_eq!(id, id_a, "arena assigned a different instance id");
        assert_eq!(id, id_s, "sharded store assigned a different instance id");
        assert_eq!(id, id_l, "lazy forest assigned a different instance id");
        self.check_structure(&format!("after add {id}"));
    }

    fn check_delete_cost(&mut self, id: u32) {
        let c_boxed: u64 = (0..self.boxed_trees.len())
            .map(|t| {
                // Occ(q): non-owning trees are costless for `id`.
                if !owns(self.tree_seeds[t], id, self.params.q) {
                    return 0;
                }
                let ctx = TrainCtx {
                    data: &self.boxed_data,
                    params: &self.params,
                    tree_seed: self.tree_seeds[t],
                };
                boxed::delete_cost(&ctx, &self.boxed_trees[t], id, 0)
            })
            .sum();
        assert_eq!(self.arena.delete_cost(id), c_boxed, "delete_cost {id} (arena)");
        assert_eq!(
            self.sharded.delete_cost(id).unwrap(),
            c_boxed,
            "delete_cost {id} (sharded)"
        );
        // lazy: as-if-flushed — must serve the eager value at query time
        assert_eq!(
            self.lazy.delete_cost_flushed(id),
            c_boxed,
            "delete_cost {id} (lazy, as-if-flushed)"
        );
    }

    fn check_predict(&mut self, rows: &[Vec<f32>]) {
        let nt = self.boxed_trees.len() as f32;
        let expected: Vec<f32> = rows
            .iter()
            .map(|row| {
                let s: f32 = self.boxed_trees.iter().map(|t| t.predict(row)).sum();
                s / nt
            })
            .collect();
        let a = self.arena.predict_proba_rows(rows);
        let s = self.sharded.predict_proba_rows(rows);
        let l = self.lazy.predict_proba_rows_flushed(rows);
        assert_eq!(expected, a, "arena predictions diverged from the boxed oracle");
        assert_eq!(a, s, "sharded predictions diverged from the arena path");
        assert_eq!(a, l, "lazy flush-on-read predictions diverged from the eager path");
    }
}

fn fuzz_seeds() -> Vec<u64> {
    match std::env::var("DARE_FUZZ_SEEDS") {
        Ok(s) => {
            let seeds: Vec<u64> = s.split(',').filter_map(|x| x.trim().parse().ok()).collect();
            assert!(!seeds.is_empty(), "DARE_FUZZ_SEEDS set but empty");
            seeds
        }
        Err(_) => (0..22).collect(),
    }
}

fn run_case(seed: u64) {
    run_case_at_q(seed, 1.0);
}

/// One fuzzed interleaving at subsample fraction `q`. The rng stream does
/// not depend on `q`, so every q runs the *same* dataset and op sequence —
/// only ownership differs — and `q = 1.0` is literally the original case
/// (`with_subsample(1.0)` leaves `Params` at its default).
fn run_case_at_q(seed: u64, q: f64) {
    let mut rng = Rng::new(mix_seed(&[seed, 0xF0_22]));
    let n = 70 + rng.index(80);
    let p = 3 + rng.index(3);
    let data = random_dataset(&mut rng, n, p);
    let max_depth = 4 + rng.index(3);
    let params = Params {
        n_trees: 2 + rng.index(2),
        max_depth,
        k: 2 + rng.index(6),
        d_rmax: rng.index(3).min(max_depth),
        ..Default::default()
    }
    .with_subsample(q);
    let n_shards = 1 + rng.index(4);
    // Alternate lazy policies across the pinned seed list so both deferral
    // modes fuzz under every parameter mix.
    let policy = if seed % 2 == 0 {
        LazyPolicy::OnRead
    } else {
        LazyPolicy::Budgeted(1 + (seed as usize % 3))
    };
    let mut h = Harness::new(data, params, rng.next_u64(), n_shards, policy);
    h.check_structure("fresh");

    let ops = 14 + rng.index(8);
    for op in 0..ops {
        match rng.index(12) {
            0..=4 if h.n_alive() > 12 => {
                let live = h.boxed_data.live_ids();
                let id = live[rng.index(live.len())];
                h.delete(id);
            }
            5..=6 | 0..=4 => {
                let row: Vec<f32> = (0..h.boxed_data.n_features())
                    .map(|_| rng.range_f32(-4.0, 4.0))
                    .collect();
                h.add(&row, rng.bernoulli(0.5) as u8);
            }
            7..=8 => {
                let live = h.boxed_data.live_ids();
                let id = live[rng.index(live.len())];
                h.check_delete_cost(id);
            }
            9 => {
                // Explicit full flush: afterwards the lazy leg must be
                // bit-identical to the eager path (structure AND bytes).
                h.lazy.flush_all();
                assert_eq!(h.lazy.dirty_subtrees(), 0);
                h.check_lazy_flushed(&format!("after flush (op {op})"));
            }
            10 => {
                // Partial compaction: a bounded drain must keep the trees
                // internally consistent, never change logical state.
                h.lazy.compact(1 + rng.index(2));
                for t in h.lazy.trees() {
                    t.validate().unwrap();
                }
            }
            _ => {
                // Mix live rows and random probes; sizes straddle the
                // batched-prediction cutoff so both descent paths fuzz.
                let n_rows = 1 + rng.index(40);
                let live = h.boxed_data.live_ids();
                let rows: Vec<Vec<f32>> = (0..n_rows)
                    .map(|_| {
                        if rng.bernoulli(0.5) {
                            h.boxed_data.row(live[rng.index(live.len())])
                        } else {
                            (0..h.boxed_data.n_features())
                                .map(|_| rng.range_f32(-5.0, 5.0))
                                .collect()
                        }
                    })
                    .collect();
                h.check_predict(&rows);
            }
        }
        if op == ops - 1 {
            h.sharded.validate().unwrap_or_else(|e| {
                panic!("seed {seed} q {q}: sharded store inconsistent after final op: {e}")
            });
        }
    }
    // End of sequence: drain the lazy leg completely — flush-all after ANY
    // op sequence must reproduce the eager forest bit for bit.
    h.lazy.flush_all();
    h.check_lazy_flushed("after final flush");
}

#[test]
fn op_sequences_are_bit_exact_across_boxed_arena_and_sharded() {
    for seed in fuzz_seeds() {
        // A failing seed is fully reproducible: re-run with
        // DARE_FUZZ_SEEDS=<seed>.
        run_case(seed);
    }
}

/// ISSUE 8: the Occ(q) subsampling leg. The same fuzzed interleavings run
/// at q ∈ {0.1, 0.3, 1.0} against T independent single-tree oracles, each
/// trained from scratch on exactly its owned ids and gated per op on the
/// same stateless ownership predicate the production paths consult — a
/// non-owning oracle never sees the op and never advances its epoch. Every
/// structure, DeleteReport, cost, and prediction must stay bit-equal across
/// all four legs (boxed / arena / sharded / lazy), and q=1.0 re-runs the
/// exact original path (pinned byte-identical below).
#[test]
fn subsampled_op_sequences_match_per_tree_owned_oracles() {
    for seed in fuzz_seeds() {
        for q in [0.1, 0.3, 1.0] {
            run_case_at_q(seed, q);
        }
    }
}

/// `with_subsample(1.0)` is not "almost" the default path — it IS the
/// default path: fits, deletions, adds, and the serialized forest are
/// byte-identical to a forest built from untouched default `Params`.
#[test]
fn q1_subsampled_path_is_byte_identical_to_the_default_path() {
    let mut rng = Rng::new(mix_seed(&[7, 0x0CC5]));
    let data = random_dataset(&mut rng, 120, 4);
    let base = Params {
        n_trees: 3,
        max_depth: 5,
        k: 4,
        d_rmax: 1,
        ..Default::default()
    };
    let mut a = DareForest::fit(data.clone(), &base, 99);
    let mut b = DareForest::fit(data, &base.clone().with_subsample(1.0), 99);
    for id in [3u32, 40, 77] {
        let ra = a.delete(id).unwrap();
        let rb = b.delete(id).unwrap();
        assert_eq!(ra.cost(), rb.cost());
        for (x, y) in ra.per_tree.iter().zip(&rb.per_tree) {
            assert_reports_eq(x, y, "q=1.0 delete");
        }
    }
    let p = a.data().n_features();
    for i in 0..3 {
        let row = vec![0.3 * i as f32; p];
        assert_eq!(a.add(&row, (i % 2) as u8), b.add(&row, (i % 2) as u8));
    }
    assert_eq!(
        forest_to_json(&a),
        forest_to_json(&b),
        "q=1.0 must serialize byte-identically to the default path"
    );
}

/// ISSUE 5: the registry differential — two models served by ONE
/// `UnlearningService` are driven through the versioned wire surface
/// (`handle`: decode → dispatch → encode) with interleaved mutations and
/// reads, in lockstep with two standalone `ShardedForest` oracles. Every
/// wire response must be byte-identical to the oracle-derived payload
/// (probabilities f32-exact, reports field-exact), and the tenants must be
/// fully isolated: a fixed probe's prediction bytes on one model are
/// unchanged by any mutation of the other. Runs under the ambient
/// `DARE_LAZY_POLICY` (the oracles adopt the same policy), so the CI
/// matrix fuzzes the registry in both deferral modes.
#[test]
fn registry_two_model_interleavings_match_standalone_stores() {
    use dare::util::json::parse;
    for seed in [3u64, 11, 19, 42] {
        let mut rng = Rng::new(mix_seed(&[seed, 0x0A21]));
        let policy = dare::forest::LazyPolicy::from_env();
        let mk = |rng: &mut Rng| {
            let n = 60 + rng.index(60);
            let p = 3 + rng.index(3);
            let data = random_dataset(rng, n, p);
            let max_depth = 4 + rng.index(2);
            let params = Params {
                n_trees: 2 + rng.index(2),
                max_depth,
                k: 2 + rng.index(5),
                d_rmax: rng.index(2).min(max_depth),
                ..Default::default()
            };
            let fseed = rng.next_u64();
            (data, params, fseed)
        };
        let (da, pa, sa) = mk(&mut rng);
        let (db, pb, sb) = mk(&mut rng);
        // one service, two tenants; oracles mirror forest + policy exactly
        // (shard counts are free — sharding is bit-exact routing)
        let svc = UnlearningService::with_models(
            vec![
                ("alpha".to_string(), DareForest::fit(da.clone(), &pa, sa)),
                ("beta".to_string(), DareForest::fit(db.clone(), &pb, sb)),
            ],
            ServiceConfig {
                batch_window: std::time::Duration::from_millis(1),
                use_pjrt: false,
                n_shards: 2,
                lazy: policy,
                // the compactor's nondeterministic timing must not race the
                // byte comparisons below
                compact_interval: std::time::Duration::from_secs(3600),
                ..Default::default()
            },
        );
        let oracles = [
            ShardedForest::new_with_policy(DareForest::fit(da, &pa, sa), 3, policy),
            ShardedForest::new_with_policy(DareForest::fit(db, &pb, sb), 1, policy),
        ];
        let names = ["alpha", "beta"];

        // fixed probe per tenant; served bytes must only move when THAT
        // tenant mutates
        let probes: Vec<String> = oracles
            .iter()
            .map(|o| {
                let row: Vec<String> =
                    o.with_data(|d| d.row(0)).iter().map(|v| v.to_string()).collect();
                row.join(",")
            })
            .collect();
        let probe_req = |m: usize| {
            parse(&format!(
                r#"{{"v":1,"model":"{}","op":"predict","rows":[[{}]]}}"#,
                names[m], probes[m]
            ))
            .unwrap()
        };
        let mut probe_bytes: Vec<String> =
            (0..2).map(|m| svc.handle(&probe_req(m)).to_string()).collect();

        for _op in 0..24 {
            let m = rng.index(2);
            let other = 1 - m;
            let oracle = &oracles[m];
            match rng.index(8) {
                0..=2 if oracle.n_alive() > 12 => {
                    let live = oracle.live_ids();
                    let id = live[rng.index(live.len())];
                    let actual = svc.handle(
                        &parse(&format!(
                            r#"{{"v":1,"model":"{}","op":"delete","ids":[{id}]}}"#,
                            names[m]
                        ))
                        .unwrap(),
                    );
                    let (report, skipped, deferred) = oracle.delete_batch_counted(&[id]);
                    let expected = encode_response(&Response::Delete(dare::coordinator::DeleteOutcome {
                        requested: 1,
                        deleted: 1 - skipped,
                        skipped,
                        retrain_cost: report.cost(),
                        deferred: deferred as usize,
                        batch_size: 1,
                    }));
                    assert_eq!(
                        actual.to_string(),
                        expected.to_string(),
                        "seed {seed}: delete response diverged on {}",
                        names[m]
                    );
                    // the untouched tenant's served bytes are unchanged
                    assert_eq!(
                        svc.handle(&probe_req(other)).to_string(),
                        probe_bytes[other],
                        "seed {seed}: mutating {} moved {}'s prediction",
                        names[m],
                        names[other]
                    );
                    probe_bytes[m] = svc.handle(&probe_req(m)).to_string();
                }
                3..=4 => {
                    let p = oracle.n_features();
                    let row: Vec<f32> =
                        (0..p).map(|_| rng.range_f32(-4.0, 4.0)).collect();
                    let row_s: Vec<String> = row.iter().map(|v| v.to_string()).collect();
                    let actual = svc.handle(
                        &parse(&format!(
                            r#"{{"v":1,"model":"{}","op":"add","row":[{}],"label":1}}"#,
                            names[m],
                            row_s.join(",")
                        ))
                        .unwrap(),
                    );
                    let id = oracle.add(&row, 1).unwrap();
                    let expected = encode_response(&Response::Add { id });
                    assert_eq!(actual.to_string(), expected.to_string(), "seed {seed}: add diverged");
                    assert_eq!(
                        svc.handle(&probe_req(other)).to_string(),
                        probe_bytes[other],
                        "seed {seed}: adding to {} moved {}'s prediction",
                        names[m],
                        names[other]
                    );
                    probe_bytes[m] = svc.handle(&probe_req(m)).to_string();
                }
                5 => {
                    let live = oracle.live_ids();
                    let id = live[rng.index(live.len())];
                    let actual = svc.handle(
                        &parse(&format!(
                            r#"{{"v":1,"model":"{}","op":"delete_cost","id":{id}}}"#,
                            names[m]
                        ))
                        .unwrap(),
                    );
                    let expected = encode_response(&Response::DeleteCost {
                        cost: oracle.delete_cost(id).unwrap(),
                    });
                    assert_eq!(actual.to_string(), expected.to_string(), "seed {seed}: cost diverged");
                }
                _ => {
                    let p = oracle.n_features();
                    let n_rows = 1 + rng.index(8);
                    let rows: Vec<Vec<f32>> = (0..n_rows)
                        .map(|_| (0..p).map(|_| rng.range_f32(-5.0, 5.0)).collect())
                        .collect();
                    let rows_s: Vec<String> = rows
                        .iter()
                        .map(|r| {
                            format!(
                                "[{}]",
                                r.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
                            )
                        })
                        .collect();
                    let actual = svc.handle(
                        &parse(&format!(
                            r#"{{"v":1,"model":"{}","op":"predict","rows":[{}]}}"#,
                            names[m],
                            rows_s.join(",")
                        ))
                        .unwrap(),
                    );
                    let expected = encode_response(&Response::Predict {
                        probs: oracle.predict_proba_rows(&rows),
                        engine: "native",
                    });
                    assert_eq!(
                        actual.to_string(),
                        expected.to_string(),
                        "seed {seed}: predict diverged on {}",
                        names[m]
                    );
                }
            }
        }

        // final audit: each tenant's trees are structurally identical to
        // its standalone oracle, and both stores validate
        for (m, oracle) in oracles.iter().enumerate() {
            let model = svc.registry().get(names[m]).unwrap();
            let snap = oracle.snapshot();
            model.sharded().snapshot().trees().iter().zip(snap.trees()).enumerate().for_each(
                |(t, (a, b))| {
                    assert!(
                        a.structural_matches(b),
                        "seed {seed}: {} tree {t} diverged from its oracle",
                        names[m]
                    );
                },
            );
            model.sharded().validate().unwrap();
            oracle.validate().unwrap();
        }
    }
}

/// ISSUE 6: the durability differential (DESIGN.md §11). Every fuzzed op
/// sequence is journaled through a [`Wal`] while a live forest — running
/// under the ambient `DARE_LAZY_POLICY`, so the CI matrix covers both
/// deferral modes — applies it; a fresh `Wal::recover` must then land on
/// the byte-identical serialized forest and f32-identical predictions.
/// Replay is *eager* (snapshots are canonical flushed state, and logged
/// deletes re-apply through the eager `delete_batch` path), so this is the
/// PR-4 flush-order-invariance argument executed end-to-end through the
/// on-disk log: eager replay of the journal ≡ live-then-flush. A small
/// `snapshot_every` makes the snapshot + log-truncation dance fire
/// mid-sequence, fuzzing the epoch-filtered replay path; `EveryN` fsync
/// plus a mid-sequence recovery probe check that recovery is correct at
/// interior points, not just at rest.
#[test]
fn wal_replay_lands_on_the_live_forest_bit_for_bit() {
    use dare::coordinator::api::Op;
    use dare::coordinator::wal::{dir_name, Wal};
    use dare::coordinator::FsyncPolicy;
    use std::cell::RefCell;

    let root = std::env::temp_dir().join(format!("dare-fuzz-wal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let policy = LazyPolicy::from_env();

    for seed in fuzz_seeds() {
        let mut rng = Rng::new(mix_seed(&[seed, 0x3A17]));
        let n = 60 + rng.index(60);
        let p = 3 + rng.index(3);
        let data = random_dataset(&mut rng, n, p);
        let max_depth = 4 + rng.index(2);
        let params = Params {
            n_trees: 2 + rng.index(2),
            max_depth,
            k: 2 + rng.index(5),
            d_rmax: rng.index(2).min(max_depth),
            ..Default::default()
        };
        let mut live = DareForest::fit(data, &params, rng.next_u64());
        live.set_lazy_policy(policy);
        let live = RefCell::new(live);
        // Canonical flushed state for snapshots (fresh fits are flushed;
        // later snapshots flush a clone so the live leg's dirty set — the
        // thing under test — is never perturbed).
        let flushed = || {
            let mut c = live.borrow().clone();
            c.flush_all();
            c
        };
        let model = format!("fuzz-{seed}");
        let wal = Wal::create(
            &root,
            &model,
            &live.borrow(),
            FsyncPolicy::EveryN(3),
            4, // snapshot + truncate mid-sequence
            b"fuzz-key".to_vec(),
        )
        .unwrap();

        let ops = 12 + rng.index(8);
        let probe_at = rng.index(ops);
        for op in 0..ops {
            match rng.index(8) {
                0..=3 if live.borrow().n_alive() > 12 => {
                    let live_ids = live.borrow().live_ids();
                    let mut ids = vec![live_ids[rng.index(live_ids.len())]];
                    if rng.bernoulli(0.2) {
                        // journaled jobs may carry dead ids; replay must
                        // skip them exactly like the live path did
                        ids.push(live_ids[rng.index(live_ids.len())]);
                    }
                    wal.logged(
                        Op::Delete { ids: ids.clone() },
                        || live.borrow_mut().delete_batch(&ids),
                        &flushed,
                    )
                    .unwrap();
                }
                4..=5 | 0..=3 => {
                    let row: Vec<f32> = (0..live.borrow().data().n_features())
                        .map(|_| rng.range_f32(-4.0, 4.0))
                        .collect();
                    let label = rng.bernoulli(0.5) as u8;
                    wal.logged(
                        Op::Add {
                            row: row.clone(),
                            label,
                        },
                        || live.borrow_mut().add(&row, label),
                        &flushed,
                    )
                    .unwrap();
                }
                6 => {
                    // an explicit checkpoint truncates the log outside the
                    // snapshot_every cadence
                    wal.checkpoint(&flushed()).unwrap();
                }
                _ => {
                    // reads don't journal; drain part of the backlog so the
                    // dirty set's shape varies across the sequence
                    live.borrow_mut().compact(1 + rng.index(2));
                }
            }
            if op == probe_at {
                // crash-recover at an interior point: replaying the log as
                // written so far must reproduce the flushed live state
                let rec = Wal::recover(
                    &root,
                    &dir_name(&model),
                    FsyncPolicy::EveryOp,
                    0,
                    b"fuzz-key".to_vec(),
                )
                .unwrap_or_else(|e| panic!("seed {seed}, op {op}: recovery failed: {e}"));
                assert_eq!(
                    forest_to_json(&rec.forest),
                    forest_to_json(&flushed()),
                    "seed {seed}, op {op}: mid-sequence recovery diverged from the live leg"
                );
            }
        }

        // End of sequence: recovery must land on the live forest bit for bit.
        let final_epoch = wal.epoch();
        drop(wal);
        live.borrow_mut().flush_all();
        let expect = forest_to_json(&live.borrow());
        let rec = Wal::recover(
            &root,
            &dir_name(&model),
            FsyncPolicy::EveryOp,
            0,
            b"fuzz-key".to_vec(),
        )
        .unwrap_or_else(|e| panic!("seed {seed}: final recovery failed: {e}"));
        assert_eq!(rec.name, model);
        assert_eq!(rec.wal.epoch(), final_epoch, "seed {seed}: recovered epoch diverged");
        assert_eq!(
            forest_to_json(&rec.forest),
            expect,
            "seed {seed}: recovered forest is not byte-identical to the live leg"
        );
        let probes: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                (0..live.borrow().data().n_features())
                    .map(|_| rng.range_f32(-5.0, 5.0))
                    .collect()
            })
            .collect();
        assert_eq!(
            rec.forest.predict_proba_rows(&probes),
            live.borrow().predict_proba_rows(&probes),
            "seed {seed}: recovered predictions diverged"
        );
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// The paper's exactness theorem, executable: in the exhaustive regime
/// every deletion leaves every tree identical to retraining from scratch
/// on the surviving instances — through the arena path AND the sharded
/// coordinator (see module docs for why additions assert oracle-equality
/// in leg 1 instead).
#[test]
fn random_deletion_sequences_match_scratch_retrain_exhaustively() {
    for seed in [1u64, 2, 3, 4, 5, 6] {
        let mut rng = Rng::new(mix_seed(&[seed, 0x5C2A]));
        let n = 60 + rng.index(60);
        let p = 3 + rng.index(2);
        let data = random_dataset(&mut rng, n, p);
        let params = Params {
            n_trees: 2,
            max_depth: 5,
            k: 10_000,
            d_rmax: 0,
            max_features: MaxFeatures::All,
            ..Default::default()
        };
        let forest_seed = rng.next_u64();
        let mut arena = DareForest::fit(data.clone(), &params, forest_seed);
        let sharded = ShardedForest::new(DareForest::fit(data.clone(), &params, forest_seed), 2);
        let mut lazy = DareForest::fit(data, &params, forest_seed);
        lazy.set_lazy_policy(LazyPolicy::OnRead);
        let deletions = 10 + rng.index(6);
        for step in 0..deletions {
            if arena.n_alive() <= 15 {
                break;
            }
            let live = arena.live_ids();
            let id = live[rng.index(live.len())];
            arena.delete_seq(id).unwrap();
            let (_, skipped) = sharded.delete_batch(&[id]);
            assert_eq!(skipped, 0);
            lazy.delete_seq(id).unwrap();

            for (t, tree) in arena.trees().iter().enumerate() {
                let ctx = TrainCtx {
                    data: arena.data(),
                    params: &params,
                    tree_seed: tree_seed(forest_seed, t),
                };
                let scratch = train(&ctx, arena.data().live_ids(), 0, ROOT_PATH);
                assert!(
                    tree.matches_root(&scratch),
                    "seed {seed}, deletion {step}: tree {t} != scratch retrain \
                     on the surviving instances"
                );
            }
            sharded.for_each_tree(|gt, tree| {
                assert!(
                    tree.structural_matches(&arena.trees()[gt]),
                    "seed {seed}, deletion {step}: sharded tree {gt} diverged"
                );
            });
        }
        sharded.validate().unwrap();
        // Lazy leg: deferring every retrain and flushing at the end must
        // land on the same scratch-identical forest.
        lazy.flush_all();
        for (t, tree) in lazy.trees().iter().enumerate() {
            assert!(
                tree.structural_matches(&arena.trees()[t]),
                "seed {seed}: flushed lazy tree {t} != eager tree"
            );
        }
    }
}

/// ISSUE 7: the replication differential (DESIGN.md §12). The same fuzzed
/// op sequences as the WAL leg, but now a *follower* tails the leader's
/// journal through `read_records_after` + `apply_shipped` at random
/// cadences — sometimes per-op, sometimes lagging far enough behind a
/// truncating leader that it is told `snapshot_needed` and must
/// re-bootstrap from a fresh snapshot. Whenever the follower is caught
/// up, it must be byte-identical to what `Wal::recover` reconstructs from
/// the leader's journal at the same epoch: same serialized forest, same
/// predictions, and a local journal that itself recovers to that state.
/// Overlapping windows are re-offered on purpose: the epoch-chain rule
/// must dedup them without perturbing anything.
#[test]
fn follower_tailing_the_leader_matches_recovery_bit_for_bit() {
    use dare::coordinator::api::Op;
    use dare::coordinator::wal::{dir_name, Wal};
    use dare::coordinator::{FsyncPolicy, ReplicaState, ReplicationConfig};
    use std::cell::RefCell;

    let leader_root = std::env::temp_dir().join(format!("dare-fuzz-repl-l-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&leader_root);
    std::fs::create_dir_all(&leader_root).unwrap();
    let policy = LazyPolicy::from_env();

    for seed in fuzz_seeds() {
        // Per-seed follower root: service startup recovers every model dir
        // under its durability root, so roots must not accumulate.
        let follower_root =
            std::env::temp_dir().join(format!("dare-fuzz-repl-f-{}-{seed}", std::process::id()));
        let _ = std::fs::remove_dir_all(&follower_root);
        std::fs::create_dir_all(&follower_root).unwrap();
        let mut rng = Rng::new(mix_seed(&[seed, 0x7E91]));
        let n = 60 + rng.index(60);
        let p = 3 + rng.index(3);
        let data = random_dataset(&mut rng, n, p);
        let max_depth = 4 + rng.index(2);
        let params = Params {
            n_trees: 2 + rng.index(2),
            max_depth,
            k: 2 + rng.index(5),
            d_rmax: rng.index(2).min(max_depth),
            ..Default::default()
        };
        let mut live = DareForest::fit(data, &params, rng.next_u64());
        live.set_lazy_policy(policy);
        let live = RefCell::new(live);
        let flushed = || {
            let mut c = live.borrow().clone();
            c.flush_all();
            c
        };
        let model_name = format!("repl-{seed}");
        // A small snapshot_every makes the leader truncate mid-sequence, so
        // lagging followers hit the snapshot_needed path and re-bootstrap.
        let leader_wal = Wal::create(
            &leader_root,
            &model_name,
            &live.borrow(),
            FsyncPolicy::EveryN(3),
            5,
            b"fuzz-key".to_vec(),
        )
        .unwrap();

        // The follower lives in a real service so shipped records flow
        // through the same Model/ShardedForest/WAL plumbing as production.
        let fsvc = UnlearningService::with_models(
            Vec::new(),
            ServiceConfig {
                use_pjrt: false,
                n_shards: 1 + rng.index(3),
                wal_dir: Some(follower_root.clone()),
                wal_snapshot_every: 0,
                cert_key: Some("fuzz-key".to_string()),
                ..Default::default()
            },
        );
        let never = ReplicationConfig {
            leader: "127.0.0.1:1".to_string(), // tailed by hand, never dialed
            spawn_tailers: false,
            ..Default::default()
        };
        // Bootstrap generation 0 from the leader's epoch-0 snapshot. Each
        // re-bootstrap after a truncation installs a new generation.
        let mut generation = 0u32;
        let (e0, snap0) = leader_wal.snapshot_with_epoch(&flushed);
        let gen_name = |g: u32| format!("{model_name}.g{g}");
        let mut fmodel = fsvc.install_snapshot(&gen_name(0), &snap0, e0).unwrap();
        let mut rep = ReplicaState::new(never.clone(), e0);
        fmodel.attach_replica(std::sync::Arc::clone(&rep));

        let ops = 12 + rng.index(8);
        for op in 0..ops {
            // Mutate the leader (journaled, exactly like the service does).
            if rng.bernoulli(0.6) && live.borrow().n_alive() > 12 {
                let live_ids = live.borrow().live_ids();
                let ids = vec![live_ids[rng.index(live_ids.len())]];
                leader_wal
                    .logged(
                        Op::Delete { ids: ids.clone() },
                        || live.borrow_mut().delete_batch(&ids),
                        &flushed,
                    )
                    .unwrap();
            } else {
                let row: Vec<f32> = (0..live.borrow().data().n_features())
                    .map(|_| rng.range_f32(-4.0, 4.0))
                    .collect();
                let label = rng.bernoulli(0.5) as u8;
                leader_wal
                    .logged(
                        Op::Add { row: row.clone(), label },
                        || live.borrow_mut().add(&row, label),
                        &flushed,
                    )
                    .unwrap();
            }

            // Tail at a random cadence, with randomly sized (and sometimes
            // deliberately overlapping) pull windows.
            if rng.bernoulli(0.6) || op == ops - 1 {
                loop {
                    let from = if rng.bernoulli(0.25) {
                        rep.applied_epoch().saturating_sub(2) // overlap: dedup must absorb it
                    } else {
                        rep.applied_epoch()
                    };
                    let batch = leader_wal.read_records_after(from, 1 + rng.index(4));
                    rep.note_leader_epoch(batch.leader_epoch);
                    if batch.snapshot_needed {
                        // The leader truncated past us: re-bootstrap from a
                        // fresh snapshot, exactly like a cold follower.
                        generation += 1;
                        let (e, snap) = leader_wal.snapshot_with_epoch(&flushed);
                        // shipped records must point at the follower model
                        fmodel = fsvc.install_snapshot(&gen_name(generation), &snap, e).unwrap();
                        rep = ReplicaState::new(never.clone(), e);
                        fmodel.attach_replica(std::sync::Arc::clone(&rep));
                        continue;
                    }
                    if batch.records.is_empty() {
                        break;
                    }
                    for rec in &batch.records {
                        // records carry the leader's model name; re-target
                        // the follower's generation-suffixed registry entry
                        let mut rec = rec.clone();
                        rec.request.model = gen_name(generation);
                        rep.apply_shipped(&fmodel, &rec).unwrap_or_else(|e| {
                            panic!("seed {seed}, op {op}: apply_shipped failed: {e}")
                        });
                    }
                    if rep.applied_epoch() >= batch.leader_epoch {
                        break;
                    }
                }
                assert_eq!(rep.lag_epochs(), 0, "seed {seed}, op {op}: tail did not drain");
            }
        }

        // Caught up: the follower must be byte-identical to leader recovery
        // at the same epoch.
        let final_epoch = leader_wal.epoch();
        assert_eq!(rep.applied_epoch(), final_epoch, "seed {seed}: final tail incomplete");
        drop(leader_wal);
        let rec = Wal::recover(
            &leader_root,
            &dir_name(&model_name),
            FsyncPolicy::EveryOp,
            0,
            b"fuzz-key".to_vec(),
        )
        .unwrap_or_else(|e| panic!("seed {seed}: leader recovery failed: {e}"));
        let expect = forest_to_json(&rec.forest);
        assert_eq!(
            forest_to_json(&fmodel.snapshot_forest()),
            expect,
            "seed {seed}: follower diverged from leader recovery at epoch {final_epoch}"
        );
        let probes: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                (0..live.borrow().data().n_features())
                    .map(|_| rng.range_f32(-5.0, 5.0))
                    .collect()
            })
            .collect();
        assert_eq!(
            fmodel.sharded().predict_proba_rows(&probes),
            rec.forest.predict_proba_rows(&probes),
            "seed {seed}: follower predictions diverged"
        );
        // ...and the follower's own journal recovers to the same bytes, so
        // a follower restart needs no history re-pull.
        let frec = Wal::recover(
            &follower_root,
            &dir_name(&gen_name(generation)),
            FsyncPolicy::EveryOp,
            0,
            b"fuzz-key".to_vec(),
        )
        .unwrap_or_else(|e| panic!("seed {seed}: follower recovery failed: {e}"));
        assert_eq!(forest_to_json(&frec.forest), expect, "seed {seed}: follower journal diverged");
        assert_eq!(frec.wal.epoch(), final_epoch);
        let _ = std::fs::remove_dir_all(&follower_root);
    }
    let _ = std::fs::remove_dir_all(&leader_root);
}

/// Leg 4 (ISSUE 9): fuzz the scenario harness itself. Each seed draws a
/// randomized multi-tenant script (`ScenarioKind::Fuzz`: adds, single and
/// dead-id deletes, adversarial targets, cost reads, flush/compact/stats)
/// — compiled once, replayed twice against a fresh service each time.
/// Determinism contract (DESIGN.md §14): replays of one compiled script
/// are byte-identical in final forest state and identical in per-op
/// counts; latencies are the only free variable. The first replay also
/// runs the full cross-check (differential oracle, telemetry coherence),
/// so this leg fuzzes the checker as much as the service.
#[test]
fn fuzzed_scenarios_replay_deterministically() {
    use dare::exp::scenarios::{cross_check, replay, Scenario, ScenarioKind};

    for seed in fuzz_seeds().into_iter().take(4) {
        let sc = Scenario {
            kind: ScenarioKind::Fuzz,
            scale: 160,
            seed: mix_seed(&[seed, 0x5CE2]),
        };
        let compiled = sc.compile();
        // The spec is a pure function of its seed: an independent compile
        // must agree op-for-op (and PartialEq sees rows, ids, and routing).
        assert_eq!(
            compiled.ops,
            sc.compile().ops,
            "seed {seed}: scenario compilation is not deterministic"
        );

        let first = replay(&compiled);
        cross_check(&compiled, &first);

        let second = replay(&compiled);
        assert_eq!(
            first.final_snapshots(&compiled),
            second.final_snapshots(&compiled),
            "seed {seed}: scenario replay diverged in final forest state"
        );
        assert_eq!(
            first.op_counts(),
            second.op_counts(),
            "seed {seed}: scenario replay diverged in per-op counts"
        );
    }
}

/// Leg 5 (ISSUE 10): scheduled execution is byte-identical to direct
/// `handle()`. Each seed draws a fuzzed multi-tenant script and replays
/// it twice against fresh services — once straight through
/// `UnlearningService::handle`, once `submit`ted to a DESIGN.md §15
/// `Scheduler` and drained in time-budgeted `run_for` cycles (EDF + DRR
/// cross-tenant reordering, per-tenant FIFO preserved). Final forest
/// state must match byte-for-byte and both replays must pass the full
/// cross-check under the ambient `DARE_LAZY_POLICY` — CI runs this leg
/// in both halves of the lazy matrix. Seeds alternate between the fuzz
/// vocabulary (every op kind, dead-id deletes) and the burst shape
/// (synchronized arrival spikes), so the scheduler sees both sparse and
/// saturated queues.
#[test]
fn fuzzed_scheduled_execution_matches_direct_handle() {
    use dare::exp::scenarios::{
        cross_check, replay, replay_scheduled, Scenario, ScenarioKind,
    };
    use std::time::Duration;

    for (i, seed) in fuzz_seeds().into_iter().take(4).enumerate() {
        let kind = if i % 2 == 0 {
            ScenarioKind::Fuzz
        } else {
            ScenarioKind::Burst
        };
        let sc = Scenario {
            kind,
            scale: 120,
            seed: mix_seed(&[seed, 0x5CED]),
        };
        let compiled = sc.compile();

        let direct = replay(&compiled);
        cross_check(&compiled, &direct);

        let sched = replay_scheduled(&compiled, Duration::from_millis(3));
        cross_check(&compiled, &sched.replayed);
        assert_eq!(
            direct.final_snapshots(&compiled),
            sched.replayed.final_snapshots(&compiled),
            "seed {seed} ({kind:?}): scheduled execution diverged from direct \
             handle() in final forest state"
        );
        assert_eq!(
            direct.op_counts(),
            sched.replayed.op_counts(),
            "seed {seed} ({kind:?}): scheduled replay diverged in per-op counts"
        );
        for r in &sched.cycles {
            if r.executed > 0 {
                assert!(
                    r.spent_s <= r.budget_s + r.last_cost_s + 0.05,
                    "seed {seed}: budget cycle overran (spent {} budget {})",
                    r.spent_s,
                    r.budget_s
                );
            }
        }
    }
}
