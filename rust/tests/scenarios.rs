//! Scenario-harness smoke suite (DESIGN.md §14): each canonical scenario
//! compiles at `DARE_SCENARIO_SCALE` (CI pins 2000; default 400), replays
//! against the full coordinator stack under the ambient
//! `DARE_LAZY_POLICY`, and must
//!
//! 1. pass [`cross_check`] — differential-oracle byte equality, probe
//!    prediction bit equality, telemetry coherence, and the attached
//!    scenario checks (from-scratch retrain, exact accuracy recovery);
//! 2. replay *reproducibly*: a second replay of the same compiled script
//!    yields byte-identical final snapshots and identical per-op counts
//!    (latencies are the only thing allowed to differ between replays).
//!
//! Plus: compile determinism across processes' worth of state (two
//! independent compiles), and the pinned `BENCH_scenarios.json` schema.

use dare::exp::scenarios::{
    cross_check, replay, replay_scheduled, report_json, scenario_json, scenario_scale,
    Scenario, ScenarioKind,
};
use std::time::Duration;

/// Compile → replay → cross-check → replay again; the second pass must
/// reproduce the first bit-for-bit (snapshots) and count-for-count.
fn run_scenario(kind: ScenarioKind) {
    let sc = Scenario {
        kind,
        scale: scenario_scale(),
        seed: 0xCAFE + kind as u64,
    };
    let compiled = sc.compile();
    assert!(!compiled.ops.is_empty());

    let first = replay(&compiled);
    cross_check(&compiled, &first);

    let second = replay(&compiled);
    assert_eq!(
        first.final_snapshots(&compiled),
        second.final_snapshots(&compiled),
        "{}: replaying the same compiled script must reproduce the final \
         forest state byte-for-byte",
        compiled.name
    );
    assert_eq!(
        first.op_counts(),
        second.op_counts(),
        "{}: replays must agree on per-op-type counts",
        compiled.name
    );
    cross_check(&compiled, &second);
}

#[test]
fn adversarial_churn_replays_exactly() {
    run_scenario(ScenarioKind::AdversarialChurn);
}

#[test]
fn poison_purge_replays_exactly_and_recovers_accuracy() {
    run_scenario(ScenarioKind::PoisonPurge);
}

#[test]
fn sliding_window_replays_exactly() {
    run_scenario(ScenarioKind::SlidingWindow);
}

#[test]
fn multi_tenant_zipf_replays_exactly() {
    run_scenario(ScenarioKind::MultiTenantZipf);
}

/// The DESIGN.md §15 scheduler leg: the burst scenario (synchronized
/// multi-tenant arrival spikes) replayed once directly and once through a
/// `Scheduler` with 5 ms budget cycles. Scheduled serving must be
/// byte-identical on every tenant's final snapshot, pass the full
/// cross-check (differential oracle + telemetry coherence — the telemetry
/// ledger fills through the identical `handle` path), keep every budget
/// cycle's overrun bounded by the last ticket's measured cost, and keep
/// the p99 submit→response sojourn under the budget-derived bound
/// `cycles × (budget + max last-ticket cost)` — the drain loop's total
/// extent, which is the worst any ticket can wait.
#[test]
fn burst_replays_exactly_through_the_scheduler() {
    run_scenario(ScenarioKind::Burst);

    let sc = Scenario {
        kind: ScenarioKind::Burst,
        scale: scenario_scale(),
        seed: 0xCAFE + ScenarioKind::Burst as u64,
    };
    let compiled = sc.compile();
    let direct = replay(&compiled);
    cross_check(&compiled, &direct);

    let budget = Duration::from_millis(5);
    let sched = replay_scheduled(&compiled, budget);
    cross_check(&compiled, &sched.replayed);
    assert_eq!(
        direct.final_snapshots(&compiled),
        sched.replayed.final_snapshots(&compiled),
        "burst: scheduled execution diverged from direct handle()"
    );
    assert_eq!(direct.op_counts(), sched.replayed.op_counts());

    // Budget packing: arithmetic-robust per-cycle bound (real clock, so a
    // bookkeeping slop term; the exact bound is in the unit suite).
    assert!(!sched.cycles.is_empty(), "burst backlog must span budget cycles");
    let mut max_last_cost = 0.0f64;
    for r in &sched.cycles {
        if r.executed > 0 {
            assert!(
                r.spent_s <= r.budget_s + r.last_cost_s + 0.05,
                "burst: cycle overran: spent {} budget {} last {}",
                r.spent_s,
                r.budget_s,
                r.last_cost_s
            );
            max_last_cost = max_last_cost.max(r.last_cost_s);
        }
    }

    // p99 sojourn ≤ the budget-derived bound on the drain loop's extent.
    let bound =
        sched.cycles.len() as f64 * (budget.as_secs_f64() + max_last_cost) + 0.25;
    let p99 = sched.sojourn.p99();
    assert!(
        p99 <= bound,
        "burst: p99 sojourn {p99}s exceeds budget-derived bound {bound}s \
         ({} cycles)",
        sched.cycles.len()
    );
}

#[test]
fn compilation_is_a_pure_function_of_the_spec() {
    for sc in Scenario::canonical(scenario_scale().min(120)) {
        let a = sc.compile();
        let b = sc.compile();
        assert_eq!(a.ops, b.ops, "{}: op streams diverged across compiles", a.name);
        assert_eq!(
            a.tenants.len(),
            b.tenants.len(),
            "{}: tenant sets diverged",
            a.name
        );
    }
}

/// `BENCH_scenarios.json` schema pin: downstream tooling (CI artifact
/// diffing, the perf-history scripts) reads these exact keys. Extending
/// the schema is fine; renaming or dropping keys is a breaking change that
/// must be made deliberately, here.
#[test]
fn bench_schema_is_pinned() {
    let sc = Scenario {
        kind: ScenarioKind::Fuzz,
        scale: 80,
        seed: 42,
    };
    let compiled = sc.compile();
    let r = replay(&compiled);
    let entry = scenario_json(&compiled, &r);
    let report = report_json(80, vec![entry]);

    assert_eq!(report.get("suite").unwrap().as_str(), Some("scenarios"));
    assert_eq!(report.get("scale").unwrap().as_u64(), Some(80));
    assert!(report.get("lazy_policy").unwrap().as_str().is_some());

    let scenarios = report.get("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(scenarios.len(), 1);
    let s = &scenarios[0];
    for key in ["name", "seed", "tenants", "ops_total", "wall_s", "ops"] {
        assert!(s.get(key).is_some(), "scenario entry missing '{key}'");
    }
    let ops = s.get("ops").unwrap();
    let pred = ops.get("predict").expect("fuzz scripts always predict");
    for key in [
        "count", "mean_s", "min_s", "max_s", "p50_s", "p95_s", "p99_s",
    ] {
        assert!(pred.get(key).is_some(), "histogram entry missing '{key}'");
    }
    // Total op mass in the report equals the script length.
    let total = s.get("ops_total").unwrap().as_u64().unwrap();
    assert_eq!(total, compiled.ops.len() as u64);
}
