//! Scenario-harness smoke suite (DESIGN.md §14): each canonical scenario
//! compiles at `DARE_SCENARIO_SCALE` (CI pins 2000; default 400), replays
//! against the full coordinator stack under the ambient
//! `DARE_LAZY_POLICY`, and must
//!
//! 1. pass [`cross_check`] — differential-oracle byte equality, probe
//!    prediction bit equality, telemetry coherence, and the attached
//!    scenario checks (from-scratch retrain, exact accuracy recovery);
//! 2. replay *reproducibly*: a second replay of the same compiled script
//!    yields byte-identical final snapshots and identical per-op counts
//!    (latencies are the only thing allowed to differ between replays).
//!
//! Plus: compile determinism across processes' worth of state (two
//! independent compiles), and the pinned `BENCH_scenarios.json` schema.

use dare::exp::scenarios::{
    cross_check, replay, report_json, scenario_json, scenario_scale, Scenario, ScenarioKind,
};

/// Compile → replay → cross-check → replay again; the second pass must
/// reproduce the first bit-for-bit (snapshots) and count-for-count.
fn run_scenario(kind: ScenarioKind) {
    let sc = Scenario {
        kind,
        scale: scenario_scale(),
        seed: 0xCAFE + kind as u64,
    };
    let compiled = sc.compile();
    assert!(!compiled.ops.is_empty());

    let first = replay(&compiled);
    cross_check(&compiled, &first);

    let second = replay(&compiled);
    assert_eq!(
        first.final_snapshots(&compiled),
        second.final_snapshots(&compiled),
        "{}: replaying the same compiled script must reproduce the final \
         forest state byte-for-byte",
        compiled.name
    );
    assert_eq!(
        first.op_counts(),
        second.op_counts(),
        "{}: replays must agree on per-op-type counts",
        compiled.name
    );
    cross_check(&compiled, &second);
}

#[test]
fn adversarial_churn_replays_exactly() {
    run_scenario(ScenarioKind::AdversarialChurn);
}

#[test]
fn poison_purge_replays_exactly_and_recovers_accuracy() {
    run_scenario(ScenarioKind::PoisonPurge);
}

#[test]
fn sliding_window_replays_exactly() {
    run_scenario(ScenarioKind::SlidingWindow);
}

#[test]
fn multi_tenant_zipf_replays_exactly() {
    run_scenario(ScenarioKind::MultiTenantZipf);
}

#[test]
fn compilation_is_a_pure_function_of_the_spec() {
    for sc in Scenario::canonical(scenario_scale().min(120)) {
        let a = sc.compile();
        let b = sc.compile();
        assert_eq!(a.ops, b.ops, "{}: op streams diverged across compiles", a.name);
        assert_eq!(
            a.tenants.len(),
            b.tenants.len(),
            "{}: tenant sets diverged",
            a.name
        );
    }
}

/// `BENCH_scenarios.json` schema pin: downstream tooling (CI artifact
/// diffing, the perf-history scripts) reads these exact keys. Extending
/// the schema is fine; renaming or dropping keys is a breaking change that
/// must be made deliberately, here.
#[test]
fn bench_schema_is_pinned() {
    let sc = Scenario {
        kind: ScenarioKind::Fuzz,
        scale: 80,
        seed: 42,
    };
    let compiled = sc.compile();
    let r = replay(&compiled);
    let entry = scenario_json(&compiled, &r);
    let report = report_json(80, vec![entry]);

    assert_eq!(report.get("suite").unwrap().as_str(), Some("scenarios"));
    assert_eq!(report.get("scale").unwrap().as_u64(), Some(80));
    assert!(report.get("lazy_policy").unwrap().as_str().is_some());

    let scenarios = report.get("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(scenarios.len(), 1);
    let s = &scenarios[0];
    for key in ["name", "seed", "tenants", "ops_total", "wall_s", "ops"] {
        assert!(s.get(key).is_some(), "scenario entry missing '{key}'");
    }
    let ops = s.get("ops").unwrap();
    let pred = ops.get("predict").expect("fuzz scripts always predict");
    for key in [
        "count", "mean_s", "min_s", "max_s", "p50_s", "p95_s", "p99_s",
    ] {
        assert!(pred.get(key).is_some(), "histogram entry missing '{key}'");
    }
    // Total op mass in the report equals the script length.
    let total = s.get("ops_total").unwrap().as_u64().unwrap();
    assert_eq!(total, compiled.ops.len() as u64);
}
