//! Cross-check (ISSUE 1 + ISSUE 2 acceptance): the sort-free workspace
//! training path must be bit-exact — `structural_eq` — with the seed
//! gather+sort path across seeds, d_rmax settings and split criteria, and
//! **arena-built trees** (`DareTree::fit`, the live representation since the
//! arena refactor) must match both across the same grid. Deletion sequences
//! (whose subtree retrains run through the workspace and graft into the
//! arena) must still match retraining from scratch on the updated data.

use dare::data::dataset::Dataset;
use dare::data::synth::{generate, SynthSpec};
use dare::forest::train::{train, TrainCtx, ROOT_PATH};
use dare::forest::workspace::train_subtree;
use dare::forest::{structural_eq, DareTree, MaxFeatures, Params, SplitCriterion};
use dare::util::rng::Rng;

fn synth(n: usize, seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            n,
            informative: 4,
            redundant: 2,
            noise: 3,
            flip: 0.08,
            ..Default::default()
        },
        seed,
    )
}

/// Tentpole invariant: optimized training is bit-exact with the seed path
/// over ≥3 data seeds × d_rmax ∈ {0, 2} × {gini, entropy} × 3 tree seeds.
#[test]
fn workspace_matches_seed_path_across_grid() {
    for &data_seed in &[1u64, 2, 3] {
        let data = synth(600, data_seed);
        for &d_rmax in &[0usize, 2] {
            for &criterion in &[SplitCriterion::Gini, SplitCriterion::Entropy] {
                let params = Params {
                    n_trees: 1,
                    max_depth: 9,
                    k: 5,
                    d_rmax,
                    criterion,
                    max_features: MaxFeatures::Sqrt,
                    ..Default::default()
                };
                for tree_seed in 0..3u64 {
                    let ctx = TrainCtx {
                        data: &data,
                        params: &params,
                        tree_seed,
                    };
                    let seed_tree = train(&ctx, data.live_ids(), 0, ROOT_PATH);
                    let ws_tree = train_subtree(&ctx, data.live_ids(), 0, ROOT_PATH);
                    assert!(
                        structural_eq(&seed_tree, &ws_tree),
                        "workspace != seed path (data_seed={data_seed}, d_rmax={d_rmax}, \
                         criterion={criterion:?}, tree_seed={tree_seed})"
                    );
                    // ISSUE 2: the arena-backed tree must match the boxed
                    // builder across the same grid.
                    let arena_tree = DareTree::fit(&data, &params, tree_seed);
                    assert!(
                        arena_tree.matches_root(&seed_tree),
                        "arena != seed path (data_seed={data_seed}, d_rmax={d_rmax}, \
                         criterion={criterion:?}, tree_seed={tree_seed})"
                    );
                    arena_tree.arena.validate().unwrap();
                }
            }
        }
    }
}

/// With exhaustive thresholds (k ≥ all valid) and all attributes considered,
/// a deletion sequence — whose invalidation-triggered subtree retrains go
/// through the workspace — must keep the tree structurally identical to
/// scratch training on the updated data, on BOTH training paths.
#[test]
fn deletion_sequences_still_match_scratch_retrain() {
    let mut d = synth(300, 7);
    let params = Params {
        n_trees: 1,
        max_depth: 6,
        k: 10_000,
        d_rmax: 0,
        max_features: MaxFeatures::All,
        ..Default::default()
    };
    let mut tree = DareTree::fit(&d, &params, 9);
    let mut rng = Rng::new(42);
    for epoch in 0..30u64 {
        let live = d.live_ids();
        let id = live[rng.index(live.len())];
        tree.delete(&d, &params, id);
        d.mark_removed(id);

        let ctx = TrainCtx {
            data: &d,
            params: &params,
            tree_seed: 9,
        };
        let scratch_seed = train(&ctx, d.live_ids(), 0, ROOT_PATH);
        let scratch_ws = train_subtree(&ctx, d.live_ids(), 0, ROOT_PATH);
        assert!(
            tree.matches_root(&scratch_seed),
            "delete != scratch retrain (seed path) after epoch {epoch}"
        );
        assert!(
            tree.matches_root(&scratch_ws),
            "delete != scratch retrain (workspace path) after epoch {epoch}"
        );
        tree.arena.validate().unwrap();
    }
}

/// R-DaRE (random upper layers) exactness under deletion with workspace
/// retrains: invariants tie cached stats to data, and the forest stays
/// usable after a long deletion run.
#[test]
fn rdare_deletion_run_stays_consistent_with_workspace_retrains() {
    let mut d = synth(500, 11);
    let params = Params {
        n_trees: 1,
        max_depth: 8,
        k: 5,
        d_rmax: 3,
        max_features: MaxFeatures::Sqrt,
        ..Default::default()
    };
    let mut tree = DareTree::fit(&d, &params, 21);
    let mut rng = Rng::new(5);
    for _ in 0..200u64 {
        let live = d.live_ids();
        let id = live[rng.index(live.len())];
        tree.delete(&d, &params, id);
        d.mark_removed(id);
        assert_eq!(tree.n() as usize, d.n_alive());
    }
    tree.arena.validate().unwrap();
    // surviving tree still predicts sane probabilities
    for id in d.live_ids().into_iter().take(50) {
        let p = tree.predict(&d.row(id));
        assert!((0.0..=1.0).contains(&p));
    }
}
