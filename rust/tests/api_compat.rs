//! ISSUE 5: the wire-compat contract of the typed, versioned API.
//!
//! 1. **v0 ⇄ v1 byte identity** — a v0 request (no `"v"`/`"model"` keys)
//!    and its v1 equivalent addressed to `"default"` must produce
//!    byte-identical response payloads across all data-plane ops (stats is
//!    compared with its time-varying `telemetry` sub-object stripped).
//! 2. **Error taxonomy** — one malformed input per `ApiError` variant,
//!    asserting the stable machine-readable `code` plus the `error_msg`
//!    string alias v0 callers read.
//! 3. **Lifecycle** — create/list/save/drop/load through the wire, with
//!    the reloaded model serving byte-identical predictions.

use dare::coordinator::{ServiceConfig, UnlearningService, DEFAULT_MODEL};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params};
use dare::util::json::{parse, Value};
use std::sync::Arc;
use std::time::Duration;

fn fresh_service() -> Arc<UnlearningService> {
    let d = generate(
        &SynthSpec {
            n: 180,
            informative: 3,
            redundant: 0,
            noise: 2,
            flip: 0.05,
            ..Default::default()
        },
        41,
    );
    let f = DareForest::fit(
        d,
        &Params {
            n_trees: 4,
            max_depth: 5,
            k: 5,
            d_rmax: 1,
            ..Default::default()
        },
        43,
    );
    UnlearningService::new(
        f,
        ServiceConfig {
            batch_window: Duration::from_millis(1),
            use_pjrt: false,
            n_shards: 2,
            // keep the background compactor out of the byte comparisons
            compact_interval: Duration::from_secs(3600),
            ..Default::default()
        },
    )
}

fn req(s: &str) -> Value {
    parse(s).unwrap()
}

#[test]
fn v0_and_v1_produce_byte_identical_data_plane_responses() {
    // Two identically-seeded services; one driven with v0 requests, the
    // other with the v1 equivalents addressed to "default". Every response
    // pair must serialize to the same bytes.
    let v0 = fresh_service();
    let v1 = fresh_service();
    let p = v0.n_features();
    let row = vec!["0.3"; p].join(",");
    let short = vec!["0.3"; p.saturating_sub(1)].join(",");

    let pairs = [
        // predict (single + batch)
        format!(r#"{{"op":"predict","rows":[[{row}]]}}"#),
        format!(r#"{{"op":"predict","rows":[[{row}],[{row}]]}}"#),
        // delete: live ids, dead ids, mixed
        r#"{"op":"delete","ids":[1,2,3]}"#.to_string(),
        r#"{"op":"delete","ids":[1,4]}"#.to_string(),
        // add
        format!(r#"{{"op":"add","row":[{row}],"label":1}}"#),
        // delete_cost: live + dead (typed error path)
        r#"{"op":"delete_cost","id":9}"#.to_string(),
        r#"{"op":"delete_cost","id":999999}"#.to_string(),
        // arity error path
        format!(r#"{{"op":"predict","rows":[[{short}]]}}"#),
        // lazy-pipeline data-plane ops (no-ops under eager; same marks
        // under the DARE_LAZY_POLICY matrix leg)
        r#"{"op":"compact","budget":2}"#.to_string(),
        r#"{"op":"flush"}"#.to_string(),
    ];
    for v0_req in &pairs {
        let v1_req = {
            let mut o = parse(v0_req).unwrap();
            o.set("v", 1u64).set("model", DEFAULT_MODEL);
            o
        };
        let r0 = v0.handle(&req(v0_req));
        let r1 = v1.handle(&v1_req);
        assert_eq!(
            r0.to_string(),
            r1.to_string(),
            "v0/v1 responses diverged for {v0_req}"
        );
    }

    // stats: identical up to the time-varying telemetry sub-object
    let mut s0 = v0.handle(&req(r#"{"op":"stats"}"#));
    let mut s1 = v1.handle(&req(&format!(
        r#"{{"v":1,"model":"{DEFAULT_MODEL}","op":"stats"}}"#
    )));
    assert!(s0.remove("telemetry").is_some());
    assert!(s1.remove("telemetry").is_some());
    assert_eq!(s0.to_string(), s1.to_string(), "stats payloads diverged");

    // save: both snapshots must be byte-identical on disk
    let p0 = std::env::temp_dir().join("dare_api_compat_v0.json");
    let p1 = std::env::temp_dir().join("dare_api_compat_v1.json");
    let r0 = v0.handle(&req(&format!(r#"{{"op":"save","path":"{}"}}"#, p0.display())));
    let r1 = v1.handle(&req(&format!(
        r#"{{"v":1,"model":"{DEFAULT_MODEL}","op":"save","path":"{}"}}"#,
        p1.display()
    )));
    assert_eq!(r0.to_string(), r1.to_string());
    assert_eq!(
        std::fs::read_to_string(&p0).unwrap(),
        std::fs::read_to_string(&p1).unwrap(),
        "the two wire paths snapshotted different models"
    );
    std::fs::remove_file(&p0).ok();
    std::fs::remove_file(&p1).ok();

    v0.sharded().validate().unwrap();
    v1.sharded().validate().unwrap();
}

#[test]
fn every_api_error_variant_has_a_stable_wire_code() {
    let svc = fresh_service();
    let p = svc.n_features();
    let short = vec!["0.1"; p - 1].join(",");
    let cases: Vec<(String, &str)> = vec![
        // BadRequest: unknown op, missing payload, unsupported version
        (r#"{"op":"frobnicate"}"#.to_string(), "bad_request"),
        (r#"{"op":"predict"}"#.to_string(), "bad_request"),
        (r#"{"v":99,"op":"stats"}"#.to_string(), "bad_request"),
        // UnknownModel
        (r#"{"v":1,"model":"ghost","op":"stats"}"#.to_string(), "unknown_model"),
        // ArityMismatch (predict + add)
        (format!(r#"{{"op":"predict","rows":[[{short}]]}}"#), "arity_mismatch"),
        (format!(r#"{{"op":"add","row":[{short}],"label":0}}"#), "arity_mismatch"),
        // UnknownId
        (r#"{"op":"delete_cost","id":444444}"#.to_string(), "unknown_id"),
    ];
    for (request, code) in &cases {
        let r = svc.handle(&req(request));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{request}");
        let eo = r.get("error").unwrap();
        assert_eq!(
            eo.get("code").unwrap().as_str(),
            Some(*code),
            "wrong code for {request}"
        );
        // the v0 alias mirrors the structured message
        assert_eq!(
            r.get("error_msg").unwrap().as_str(),
            eo.get("msg").unwrap().as_str(),
            "{request}"
        );
    }
    // ArityMismatch carries the structured got/want fields
    let r = svc.handle(&req(&format!(r#"{{"op":"predict","rows":[[{short}]]}}"#)));
    let eo = r.get("error").unwrap();
    assert_eq!(eo.get("got").unwrap().as_usize(), Some(p - 1));
    assert_eq!(eo.get("want").unwrap().as_usize(), Some(p));

    // ShuttingDown: every op after shutdown is refused with the code
    svc.handle(&req(r#"{"op":"shutdown"}"#));
    let r = svc.handle(&req(r#"{"op":"stats"}"#));
    assert_eq!(
        r.get("error").unwrap().get("code").unwrap().as_str(),
        Some("shutting_down")
    );
}

#[test]
fn lifecycle_create_save_drop_load_roundtrip() {
    let svc = fresh_service();
    // create a small second tenant from a corpus dataset reference
    let r = svc.handle(&req(
        r#"{"v":1,"model":"tenant","op":"create","dataset":"twitter","scale":2000,"seed":5,"trees":3,"depth":5,"k":5}"#,
    ));
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    assert_eq!(r.get("model").unwrap().as_str(), Some("tenant"));
    assert_eq!(r.get("n_trees").unwrap().as_u64(), Some(3));

    // list shows both models with their shapes
    let r = svc.handle(&req(r#"{"v":1,"op":"list"}"#));
    let models = r.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 2);
    let names: Vec<&str> = models.iter().filter_map(|m| m.get("name").and_then(Value::as_str)).collect();
    assert_eq!(names, vec![DEFAULT_MODEL, "tenant"]);

    // unknown dataset is a typed bad_request, and the registry is unchanged
    let r = svc.handle(&req(
        r#"{"v":1,"model":"x","op":"create","dataset":"no_such_corpus"}"#,
    ));
    assert_eq!(
        r.get("error").unwrap().get("code").unwrap().as_str(),
        Some("bad_request")
    );
    // invalid hyperparameters are a typed bad_request, not a handler panic
    for bad in [
        r#"{"v":1,"model":"x","op":"create","dataset":"twitter","trees":0}"#,
        r#"{"v":1,"model":"x","op":"create","dataset":"twitter","depth":3,"drmax":5}"#,
    ] {
        let r = svc.handle(&req(bad));
        assert_eq!(
            r.get("error").unwrap().get("code").unwrap().as_str(),
            Some("bad_request"),
            "{bad}"
        );
    }
    assert_eq!(svc.registry().len(), 2);

    // mutate the tenant, snapshot it, capture a prediction
    svc.handle(&req(r#"{"v":1,"model":"tenant","op":"delete","ids":[0,1,2,3]}"#));
    let tenant_p = svc.registry().get("tenant").unwrap().n_features();
    let probe = vec!["0.25"; tenant_p].join(",");
    let before = svc.handle(&req(&format!(
        r#"{{"v":1,"model":"tenant","op":"predict","rows":[[{probe}]]}}"#
    )));
    let path = std::env::temp_dir().join("dare_api_compat_lifecycle.json");
    let r = svc.handle(&req(&format!(
        r#"{{"v":1,"model":"tenant","op":"save","path":"{}"}}"#,
        path.display()
    )));
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));

    // drop, reload under a different name: byte-identical predictions
    svc.handle(&req(r#"{"v":1,"model":"tenant","op":"drop"}"#));
    assert_eq!(svc.registry().len(), 1);
    let r = svc.handle(&req(&format!(
        r#"{{"v":1,"model":"tenant2","op":"load","path":"{}"}}"#,
        path.display()
    )));
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    let after = svc.handle(&req(&format!(
        r#"{{"v":1,"model":"tenant2","op":"predict","rows":[[{probe}]]}}"#
    )));
    assert_eq!(before.to_string(), after.to_string());
    svc.registry().get("tenant2").unwrap().sharded().validate().unwrap();
    std::fs::remove_file(&path).ok();
}

/// ISSUE 8: a subsampled tenant end to end through the wire — create with
/// `"q"`, stats surfaces the ownership fields, mutations route through the
/// Occ(q) gates, and a save/load roundtrip (the v2 snapshot format)
/// serves byte-identical predictions.
#[test]
fn lifecycle_of_a_subsampled_tenant_over_the_wire() {
    let svc = fresh_service();
    let r = svc.handle(&req(
        r#"{"v":1,"model":"occ","op":"create","dataset":"twitter","scale":2000,"seed":5,"trees":4,"depth":5,"k":5,"q":0.25}"#,
    ));
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");

    // stats reports the subsample fraction and per-tree ownership mass
    let r = svc.handle(&req(r#"{"v":1,"model":"occ","op":"stats"}"#));
    assert_eq!(r.get("subsample_q").unwrap().as_f64(), Some(0.25));
    let owned = r.get("owned_per_tree").unwrap().as_arr().unwrap();
    assert_eq!(owned.len(), 4);
    let mean = owned.iter().filter_map(Value::as_f64).sum::<f64>() / 4.0;
    let n_alive = r.get("n_alive").unwrap().as_f64().unwrap();
    assert!(
        (mean / n_alive - 0.25).abs() < 0.05,
        "mean owned fraction {} strays from q=0.25",
        mean / n_alive
    );

    // mutations route through the ownership gates; skips are observable
    svc.handle(&req(r#"{"v":1,"model":"occ","op":"delete","ids":[0,1,2,3,4,5,6,7]}"#));
    let r = svc.handle(&req(r#"{"v":1,"model":"occ","op":"stats"}"#));
    assert!(
        r.get("unowned_skips").unwrap().as_u64().unwrap() > 0,
        "8 deletions at q=0.25 over 4 trees must skip some (tree, id) pairs"
    );

    // save/load roundtrip (v2 snapshot): byte-identical predictions and a
    // store that still validates against the ownership predicate
    let occ_p = svc.registry().get("occ").unwrap().n_features();
    let probe = vec!["0.25"; occ_p].join(",");
    let before = svc.handle(&req(&format!(
        r#"{{"v":1,"model":"occ","op":"predict","rows":[[{probe}]]}}"#
    )));
    let path = std::env::temp_dir().join("dare_api_compat_subsampled.json");
    svc.handle(&req(&format!(
        r#"{{"v":1,"model":"occ","op":"save","path":"{}"}}"#,
        path.display()
    )));
    let saved = std::fs::read_to_string(&path).unwrap();
    assert!(saved.contains("dare-forest-v2"), "q<1 snapshots use the v2 tag");
    svc.handle(&req(r#"{"v":1,"model":"occ","op":"drop"}"#));
    let r = svc.handle(&req(&format!(
        r#"{{"v":1,"model":"occ2","op":"load","path":"{}"}}"#,
        path.display()
    )));
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    let after = svc.handle(&req(&format!(
        r#"{{"v":1,"model":"occ2","op":"predict","rows":[[{probe}]]}}"#
    )));
    assert_eq!(before.to_string(), after.to_string());
    let m = svc.registry().get("occ2").unwrap();
    assert_eq!(m.sharded().subsample_q(), 0.25);
    m.sharded().validate().unwrap();
    std::fs::remove_file(&path).ok();
}
