//! ISSUE 6: end-to-end crash smoke — SIGKILL the real server binary
//! mid-session and restart it on the same WAL dir (DESIGN.md §11).
//!
//! Ignored by default because it needs a built binary; CI runs it as
//!
//!   DARE_BIN=target/release/dare cargo test --release --test crash_smoke -- --ignored
//!
//! Everything the server *acked* before the kill (fsync policy every_op)
//! must survive the restart: the forest's served bytes, the absence of
//! every acked deletion, and the verifiability of certificates issued
//! before the crash.

use dare::coordinator::Client;
use std::io::{BufRead, BufReader};
use std::path::Path;
use std::process::{Child, Command, Stdio};

fn spawn_server(bin: &str, model_path: &Path, wal_dir: &Path) -> (Child, String) {
    let mut child = Command::new(bin)
        .args([
            "serve",
            "--load",
            model_path.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
            "--workers",
            "2",
            "--wal-dir",
            wal_dir.to_str().unwrap(),
            "--fsync",
            "every_op",
            "--hmac-key",
            "smoke-key",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn server");
    let stdout = child.stdout.take().unwrap();
    let mut lines = BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("server exited before binding")
            .expect("read server stdout");
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };
    // keep draining stdout so the child never blocks on a full pipe
    std::thread::spawn(move || for _ in lines {});
    (child, addr)
}

#[test]
#[ignore = "needs a built binary via DARE_BIN"]
fn sigkill_mid_session_recovers_every_acked_op() {
    let Ok(bin) = std::env::var("DARE_BIN") else {
        eprintln!("crash_smoke: DARE_BIN not set; skipping");
        return;
    };
    let root = std::env::temp_dir().join(format!("dare-smoke-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let model_path = root.join("model.json");
    let wal_dir = root.join("wal");

    // train once; both server runs load the same snapshot
    let status = Command::new(&bin)
        .args([
            "train",
            "--dataset",
            "surgical",
            "--scale",
            "2000",
            "--trees",
            "3",
            "--depth",
            "5",
            "--save",
            model_path.to_str().unwrap(),
        ])
        .status()
        .expect("run train");
    assert!(status.success(), "train failed");

    // session 1: mutate, certify, then SIGKILL without any shutdown
    let (mut child, addr) = spawn_server(&bin, &model_path, &wal_dir);
    let mut c = Client::connect(&addr).expect("connect");
    let stats = c.stats("default").unwrap();
    let n0 = stats.get("n_alive").unwrap().as_u64().unwrap();
    let p = stats.get("n_features").unwrap().as_u64().unwrap() as usize;
    assert_eq!(stats.get("durable").unwrap().as_bool(), Some(true));

    let out = c.delete("default", &[0, 3, 8]).unwrap();
    assert_eq!(out.deleted, 3);
    let added = c.add("default", &vec![0.4; p], 1).unwrap();
    c.delete("default", &[added]).unwrap();
    let cert = c.certify("default", 3).unwrap();
    assert!(c.verify_cert(&cert).unwrap());
    let probe = vec![vec![0.1_f32; p]];
    let pred = c.predict("default", &probe).unwrap();

    child.kill().expect("SIGKILL server"); // no flush, no goodbye
    child.wait().unwrap();

    // session 2: same WAL dir; acked state must be fully intact
    let (mut child2, addr2) = spawn_server(&bin, &model_path, &wal_dir);
    let mut c2 = Client::connect(&addr2).expect("reconnect");
    let stats2 = c2.stats("default").unwrap();
    assert_eq!(
        stats2.get("n_alive").unwrap().as_u64(),
        Some(n0 - 4 + 1),
        "acked mutations lost across the crash"
    );
    // three journaled records: delete[0,3,8], add, delete[added]
    assert_eq!(stats2.get("wal_epoch").unwrap().as_u64(), Some(3));
    // the acked deletions are still gone...
    for id in [0u32, 3, 8, added] {
        match c2.delete_cost("default", id) {
            Err(dare::coordinator::ApiError::UnknownId(_)) => {}
            other => panic!("deleted instance {id} resurrected: {other:?}"),
        }
    }
    // ...the pre-crash certificate still verifies, served bytes match,
    // and fresh certificates can be minted for pre-crash deletions
    assert!(c2.verify_cert(&cert).unwrap(), "pre-crash certificate rejected");
    assert_eq!(c2.predict("default", &probe).unwrap(), pred);
    let cert2 = c2.certify("default", 8).unwrap();
    assert!(c2.verify_cert(&cert2).unwrap());

    c2.shutdown().unwrap();
    child2.wait().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}
