//! Offline stand-in for the `anyhow` crate (string-backed).
//!
//! The build image has no crates.io access, so this in-repo shim provides
//! the tiny slice of anyhow's API this codebase uses — `Result`, `Error`,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Errors carry a rendered
//! message only (no backtraces, no source chains, no downcasting); like the
//! real crate, `Error` deliberately does NOT implement `std::error::Error`,
//! which is what lets the blanket `From` conversion below coexist with the
//! identity `From<Error>` used by `?`.

use std::fmt;

/// A rendered error message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints the Debug form on exit;
        // keep it human-readable like the real crate does.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {}", flag);
        Ok(7)
    }

    fn bails() -> Result<()> {
        bail!("gone");
    }

    fn io_propagates() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn macros_render_messages() {
        assert_eq!(fails(false).unwrap(), 7);
        assert_eq!(fails(true).unwrap_err().to_string(), "flag was true");
        assert_eq!(bails().unwrap_err().to_string(), "gone");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        assert_eq!(format!("{e:?}"), "x = 3");
        let from_string = anyhow!(String::from("owned"));
        assert_eq!(from_string.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_propagates().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn ensure_without_message() {
        fn check(v: usize) -> Result<()> {
            ensure!(v > 2);
            Ok(())
        }
        assert!(check(3).is_ok());
        let msg = check(1).unwrap_err().to_string();
        assert!(msg.contains("condition failed"), "{msg}");
    }
}
