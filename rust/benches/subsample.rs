//! Bench: Occ(q) subsampled ownership (ISSUE 8 / DESIGN.md §13) — delete
//! and mixed add/delete throughput at q ∈ {0.1, 0.3, 1.0} × T ∈ {10, 100}.
//!
//! Each case replays one seeded op stream against a clone of a pre-fit
//! forest. What to expect: deletion cost scales ~linearly with q (a tree
//! skips every op for instances it does not own — no statistics walk, no
//! epoch bump), so q=0.1 deletes should run close to 10× the q=1.0
//! throughput at equal T, and the gap compounds with T. Results stay
//! *exact* at every q — q trades per-tree data mass (capacity), not
//! correctness — which the mean-leaf-count proxy printed per grid point
//! makes visible: leaves per tree shrink roughly with q.
//!
//! Emits `BENCH_subsample.json` at the repo root (ns/iter per case).

use dare::bench::{BenchConfig, Suite};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params};
use dare::util::rng::Rng;

fn base_forest(n_trees: usize, q: f64) -> DareForest {
    let data = generate(
        &SynthSpec {
            n: 3000,
            informative: 4,
            redundant: 2,
            noise: 6,
            flip: 0.05,
            ..Default::default()
        },
        9,
    );
    DareForest::fit(
        data,
        &Params {
            n_trees,
            max_depth: 8,
            k: 5,
            ..Default::default()
        }
        .with_subsample(q),
        21,
    )
}

/// Delete `count` seeded live ids from a clone of `base`.
fn delete_stream(base: &DareForest, count: usize, seed: u64) {
    let mut f = base.clone();
    let mut rng = Rng::new(seed);
    for _ in 0..count {
        let live = f.live_ids();
        let id = live[rng.index(live.len())];
        std::hint::black_box(f.delete_seq(id).unwrap());
    }
}

/// Alternate adds and deletes (the add side re-tags ownership per tree
/// with probability q, so both mutation paths exercise the gate).
fn mixed_stream(base: &DareForest, count: usize, seed: u64) {
    let mut f = base.clone();
    let mut rng = Rng::new(seed);
    let p = f.data().n_features();
    for op in 0..count {
        if op % 2 == 0 {
            let live = f.live_ids();
            let id = live[rng.index(live.len())];
            std::hint::black_box(f.delete_seq(id).unwrap());
        } else {
            let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-4.0, 4.0)).collect();
            std::hint::black_box(f.add(&row, rng.bernoulli(0.5) as u8));
        }
    }
}

fn main() -> anyhow::Result<()> {
    let mut suite = Suite::new("subsample");
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 40,
        target_seconds: 2.0,
    };
    for n_trees in [10usize, 100] {
        for q in [0.1, 0.3, 1.0] {
            let base = base_forest(n_trees, q);
            // Predict-accuracy proxy: per-tree capacity at this q. Exactness
            // is invariant in q; what q trades away is data mass per tree.
            let mean_leaves = base
                .trees()
                .iter()
                .map(|t| t.shape().leaves as f64)
                .sum::<f64>()
                / n_trees as f64;
            println!("proxy t{n_trees}_q{q}: mean leaves/tree = {mean_leaves:.1}");
            suite.run(&format!("delete60_t{n_trees}_q{q}"), cfg, || {
                delete_stream(&base, 60, 0xDE1 ^ n_trees as u64);
            });
            suite.run(&format!("mixed60_t{n_trees}_q{q}"), cfg, || {
                mixed_stream(&base, 60, 0xADD ^ n_trees as u64);
            });
        }
    }
    suite.save_json_to("BENCH_subsample.json")?;
    Ok(())
}
