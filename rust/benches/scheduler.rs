//! Bench: time-budgeted scheduled serving (DESIGN.md §15) under the
//! `burst` scenario — synchronized multi-tenant arrival spikes, the shape
//! the scheduler exists for.
//!
//! The burst script is compiled once at `DARE_SCENARIO_SCALE`, replayed
//! once directly through `UnlearningService::handle` (the baseline), and
//! then through a `Scheduler` at several budget settings. For each budget
//! the report carries budget utilization (mean spent/budget across
//! `run_for` cycles), the worst per-cycle overrun, and the
//! submit→response sojourn distribution (p50/p95/p99/max) — the serving
//! latency a tenant actually experiences under the spike. Every replay is
//! cross-checked against the compiled differential oracle first, so
//! numbers from a diverged run never get written.
//!
//! Emits `BENCH_scheduler.json` at the repo root.

use dare::exp::scenarios::{
    cross_check, replay, replay_scheduled, save_report, scenario_scale, Scenario,
    ScenarioKind,
};
use dare::forest::LazyPolicy;
use dare::util::json::Value;
use std::time::Duration;

fn sojourn_json(h: &dare::util::histogram::Histogram) -> Value {
    let mut o = Value::obj();
    o.set("count", h.count())
        .set("p50_s", h.p50())
        .set("p95_s", h.p95())
        .set("p99_s", h.p99())
        .set("max_s", h.max());
    o
}

fn main() -> anyhow::Result<()> {
    let scale = scenario_scale();
    let sc = Scenario {
        kind: ScenarioKind::Burst,
        scale,
        seed: 0xB1257,
    };
    let compiled = sc.compile();

    let direct = replay(&compiled);
    cross_check(&compiled, &direct);
    println!(
        "burst (direct)     scale={} ops={} wall={:.3}s",
        scale,
        compiled.ops.len(),
        direct.wall_s
    );
    let mut direct_json = Value::obj();
    direct_json
        .set("ops_total", compiled.ops.len())
        .set("wall_s", direct.wall_s);

    let mut runs = Vec::new();
    for budget_ms in [2u64, 5, 10] {
        let budget = Duration::from_millis(budget_ms);
        let r = replay_scheduled(&compiled, budget);
        cross_check(&compiled, &r.replayed);
        assert_eq!(
            direct.final_snapshots(&compiled),
            r.replayed.final_snapshots(&compiled),
            "scheduled replay diverged from the direct baseline"
        );

        let busy: Vec<_> = r.cycles.iter().filter(|c| c.executed > 0).collect();
        let utilization = if busy.is_empty() {
            0.0
        } else {
            busy.iter().map(|c| c.spent_s / c.budget_s).sum::<f64>() / busy.len() as f64
        };
        let overrun_max_s = busy
            .iter()
            .map(|c| (c.spent_s - c.budget_s).max(0.0))
            .fold(0.0f64, f64::max);
        println!(
            "burst (sched {budget_ms:>2}ms) cycles={:<5} util={:.3} overrun_max={:.6}s \
             sojourn p50={:.6}s p99={:.6}s wall={:.3}s",
            r.cycles.len(),
            utilization,
            overrun_max_s,
            r.sojourn.p50(),
            r.sojourn.p99(),
            r.replayed.wall_s
        );

        let mut o = Value::obj();
        o.set("budget_ms", budget_ms)
            .set("cycles", r.cycles.len())
            .set("busy_cycles", busy.len())
            .set("utilization", utilization)
            .set("overrun_max_s", overrun_max_s)
            .set("wall_s", r.replayed.wall_s)
            .set("sojourn", sojourn_json(&r.sojourn));
        runs.push(o);
    }

    let mut report = Value::obj();
    report
        .set("suite", "scheduler")
        .set("scale", scale)
        .set("lazy_policy", LazyPolicy::from_env().to_string())
        .set("direct", direct_json)
        .set("scheduled", Value::Arr(runs));
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_scheduler.json");
    save_report(&out, &report)?;
    println!("wrote {}", out.display());
    Ok(())
}
