//! Bench: the PJRT runtime — L1 kernel scoring and L2 batched prediction,
//! against their native fallbacks. Requires `make artifacts`.

use dare::bench::{BenchConfig, Suite};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params, SplitCriterion};
use dare::runtime::scorer::{score_native, Counts, PjrtScorer};
use dare::runtime::{Engine, Manifest, PjrtPredictor};
use dare::util::rng::Rng;

fn main() {
    let Some(dir) = dare::runtime::manifest::locate_artifacts() else {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let engine = match Engine::global() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("pjrt engine unavailable — {e}");
            return;
        }
    };
    let mut suite = Suite::new("runtime pjrt");
    let quick = BenchConfig {
        target_seconds: 2.0,
        ..Default::default()
    };

    // --- scoring: PJRT kernel vs native -------------------------------------
    let mut rng = Rng::new(5);
    let counts: Vec<Counts> = (0..manifest.score_gini.batch)
        .map(|_| {
            let n = 2 + rng.index(10_000) as u32;
            let n_pos = rng.index(n as usize) as u32;
            let n_left = 1 + rng.index(n as usize - 1) as u32;
            Counts {
                n,
                n_pos,
                n_left,
                n_left_pos: n_pos.min(n_left),
            }
        })
        .collect();
    let scorer = PjrtScorer::new(engine, &manifest, SplitCriterion::Gini).expect("scorer");
    suite.run(
        &format!("split_scores pjrt batch={}", counts.len()),
        quick,
        || {
            std::hint::black_box(scorer.score(&counts).unwrap().len());
        },
    );
    suite.run(
        &format!("split_scores native batch={}", counts.len()),
        quick,
        || {
            std::hint::black_box(score_native(SplitCriterion::Gini, &counts).len());
        },
    );

    // --- prediction: PJRT graph vs native traversal -------------------------
    let data = generate(
        &SynthSpec {
            n: 2000,
            informative: 5,
            redundant: 3,
            noise: 8,
            flip: 0.05,
            ..Default::default()
        },
        9,
    );
    let forest = DareForest::fit(
        data.clone(),
        &Params {
            n_trees: manifest.predict.trees.min(16),
            max_depth: 10,
            k: 10,
            n_threads: 4,
            ..Default::default()
        },
        3,
    );
    let predictor = PjrtPredictor::new(engine, &manifest, &forest).expect("predictor");
    let rows: Vec<Vec<f32>> = (0..manifest.predict.batch)
        .map(|i| data.row(i as u32))
        .collect();
    suite.run(
        &format!("forest_predict pjrt batch={}", rows.len()),
        quick,
        || {
            std::hint::black_box(predictor.predict(&rows).unwrap().len());
        },
    );
    suite.run(
        &format!("forest_predict native batch={}", rows.len()),
        quick,
        || {
            std::hint::black_box(forest.predict_proba_rows(&rows).len());
        },
    );

    suite.save_json().ok();
}
