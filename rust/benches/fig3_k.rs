//! Bench: Figure 3 — k sweep (predictive performance vs deletion
//! efficiency) on Surgical (paper's headline dataset for this figure).

use dare::exp::common::ExpConfig;
use dare::exp::fig3;

fn main() {
    let scale = std::env::var("DARE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200usize);
    let dataset = std::env::var("DARE_BENCH_DATASET").unwrap_or_else(|_| "surgical".into());
    let cfg = ExpConfig {
        scale_div: scale,
        repeats: 1,
        max_deletions: 60,
        max_trees: 25,
        out_dir: "results".into(),
        ..Default::default()
    };
    let r = fig3::run(&cfg, &dataset, &[1, 5, 10, 25, 50, 100]).expect("fig3");
    println!("{}", fig3::render(&r));
}
