//! Bench: serving-path prediction throughput (ISSUE 2 acceptance): per-row
//! descent vs. level-synchronous batched blocks vs. batched + threadpool
//! fan-out, over an n_trees × batch grid. Besides the human-readable report
//! this emits `BENCH_predict.json` at the repo root with rows/s per case and
//! the headline batched-parallel vs per-row speedup at n_trees=100,
//! batch=256.

use dare::bench::{BenchConfig, Suite};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params};
use dare::util::json::Value;
use dare::util::threadpool::default_threads;

struct Case {
    name: String,
    mode: &'static str,
    n_trees: usize,
    batch: usize,
    ns_per_iter: f64,
    rows_per_sec: f64,
}

fn main() {
    let mut suite = Suite::new("predict throughput");
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 10,
        max_iters: 400,
        target_seconds: 1.5,
    };
    let data = generate(
        &SynthSpec {
            n: 8192,
            informative: 5,
            redundant: 3,
            noise: 8,
            flip: 0.05,
            ..Default::default()
        },
        3,
    );
    let threads = default_threads();
    let mut cases: Vec<Case> = Vec::new();
    let mut headline: Option<(f64, f64)> = None; // (per-row, batched+parallel) rows/s

    for &n_trees in &[10usize, 100] {
        let params = Params {
            n_trees,
            max_depth: 10,
            k: 10,
            d_rmax: 0,
            ..Default::default()
        };
        // Fit once (parallel), then share the identical trees between a
        // single-threaded and a parallel serving configuration.
        let f_par = DareForest::fit(data.clone(), &params.clone().with_threads(threads), 7);
        let f_seq = DareForest::from_parts(
            params.clone().with_threads(1),
            f_par.seed(),
            f_par.trees().to_vec(),
            f_par.data().clone(),
        )
        .expect("same trees, same data");

        for &batch in &[64usize, 256, 1024] {
            let rows: Vec<Vec<f32>> = (0..batch as u32)
                .map(|i| data.row(i % data.n_total() as u32))
                .collect();

            let per_row_mean = suite
                .run(
                    &format!("per-row       T={n_trees:<3} batch={batch}"),
                    cfg,
                    || {
                        let mut acc = 0.0f32;
                        for row in &rows {
                            acc += f_seq.predict_proba(row);
                        }
                        std::hint::black_box(acc);
                    },
                )
                .mean_s;
            let batched_mean = suite
                .run(
                    &format!("batched       T={n_trees:<3} batch={batch}"),
                    cfg,
                    || {
                        std::hint::black_box(f_seq.predict_proba_rows(&rows).len());
                    },
                )
                .mean_s;
            let par_mean = suite
                .run(
                    &format!("batched+par{threads:<2} T={n_trees:<3} batch={batch}"),
                    cfg,
                    || {
                        std::hint::black_box(f_par.predict_proba_rows(&rows).len());
                    },
                )
                .mean_s;
            for (mode, mean_s) in [
                ("per-row", per_row_mean),
                ("batched", batched_mean),
                ("batched+parallel", par_mean),
            ] {
                cases.push(Case {
                    name: format!("{mode} T={n_trees} batch={batch}"),
                    mode,
                    n_trees,
                    batch,
                    ns_per_iter: mean_s * 1e9,
                    rows_per_sec: batch as f64 / mean_s,
                });
            }

            if n_trees == 100 && batch == 256 {
                headline = Some((256.0 / per_row_mean, 256.0 / par_mean));
            }
        }
    }

    // machine-readable perf trajectory at the repo root
    let mut top = Value::obj();
    top.set("suite", "predict_throughput")
        .set("threads", threads)
        .set("rows_source", "synthetic n=8192 p=16");
    let mut arr = Vec::new();
    for c in &cases {
        let mut o = Value::obj();
        o.set("name", c.name.as_str())
            .set("mode", c.mode)
            .set("n_trees", c.n_trees)
            .set("batch", c.batch)
            .set("ns_per_iter", c.ns_per_iter)
            .set("rows_per_sec", c.rows_per_sec);
        arr.push(o);
    }
    top.set("results", Value::Arr(arr));
    if let Some((base, par)) = headline {
        let mut h = Value::obj();
        h.set("case", "n_trees=100 batch=256")
            .set("per_row_rows_per_sec", base)
            .set("batched_parallel_rows_per_sec", par)
            .set("speedup", par / base);
        top.set("headline", h);
        println!(
            "headline (T=100, batch=256): per-row {base:.0} rows/s vs batched+parallel \
             {par:.0} rows/s → {:.2}x",
            par / base
        );
    }
    let root_json =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_predict.json");
    match std::fs::write(&root_json, top.to_pretty()) {
        Ok(()) => println!("wrote {}", root_json.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", root_json.display()),
    }
    suite.save_json().ok();
}
