//! Bench: log-shipping replication (ISSUE 7 / DESIGN.md §12) — follower
//! catch-up throughput and the leader-side window cut.
//!
//! Cases:
//!   * `catchup_*` — a follower bootstrapped at epoch 0 tails a prepared
//!     1000-record leader journal to the head through
//!     `read_records_after` + `apply_shipped`, at small vs large pull
//!     windows, with and without its own journal (fsync every op). The
//!     windowed cases measure the whole shipping path minus the socket;
//!     the journaled case adds the follower's own durability cost.
//!   * `pull_window_*` — the leader-side cut alone: parse the log file
//!     and slice a window (what one `pull_log` costs the leader).
//!
//! Emits `BENCH_replication.json` at the repo root (ns/iter per case).

use dare::bench::{BenchConfig, Suite};
use dare::coordinator::api::{Op, Request};
use dare::coordinator::wal::{LogRecord, Wal};
use dare::coordinator::{FsyncPolicy, Model, ReplicaState, ReplicationConfig, ServiceConfig};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params};
use dare::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

const MODEL: &str = "bench";
const OPS: u64 = 1000;

fn base_forest() -> DareForest {
    let data = generate(
        &SynthSpec {
            n: 4000,
            informative: 4,
            redundant: 2,
            noise: 6,
            flip: 0.05,
            ..Default::default()
        },
        9,
    );
    DareForest::fit(
        data,
        &Params {
            n_trees: 10,
            max_depth: 10,
            k: 10,
            ..Default::default()
        },
        21,
    )
}

fn temp_root(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dare-bench-repl-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Journal a deterministic 1000-op mutation stream on the leader;
/// `snapshot_every: 0` keeps every record addressable for the pulls.
fn build_leader(root: &PathBuf, base: &DareForest) -> Wal {
    let mut live = base.clone();
    let wal =
        Wal::create(root, MODEL, &live, FsyncPolicy::EveryOp, 0, b"bench-key".to_vec()).unwrap();
    let p = live.data().n_features();
    let mut rng = Rng::new(0xBEEF);
    for i in 0..OPS {
        if i % 3 == 2 {
            let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-3.0, 3.0)).collect();
            wal.logged(Op::Add { row: row.clone(), label: (i % 2) as u8 }, || {
                live.add(&row, (i % 2) as u8);
            }, || {
                unreachable!("snapshot_every is 0")
            })
            .unwrap();
        } else {
            let ids = live.live_ids();
            let id = ids[rng.index(ids.len())];
            wal.logged(Op::Delete { ids: vec![id] }, || {
                live.delete_batch(&[id]);
            }, || {
                unreachable!("snapshot_every is 0")
            })
            .unwrap();
        }
    }
    wal
}

/// Tail the whole prepared journal into a fresh follower model.
fn catch_up(leader: &Wal, base: &DareForest, follower_wal: Option<Arc<Wal>>, window: usize) {
    let cfg = ServiceConfig { use_pjrt: false, n_shards: 2, ..Default::default() };
    let model = Model::new_with_wal(MODEL, base.clone(), &cfg, follower_wal);
    let rep = ReplicaState::new(
        ReplicationConfig {
            leader: "127.0.0.1:1".to_string(), // tailed in-process, never dialed
            spawn_tailers: false,
            ..Default::default()
        },
        0,
    );
    model.attach_replica(Arc::clone(&rep));
    loop {
        let batch = leader.read_records_after(rep.applied_epoch(), window);
        rep.note_leader_epoch(batch.leader_epoch);
        if batch.records.is_empty() {
            break;
        }
        for rec in &batch.records {
            rep.apply_shipped(&model, rec).unwrap();
        }
    }
    assert_eq!(rep.applied_epoch(), OPS);
}

fn main() -> anyhow::Result<()> {
    let mut suite = Suite::new("replication");
    let base = base_forest();
    let leader_root = temp_root("leader");
    let leader = build_leader(&leader_root, &base);
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 20,
        target_seconds: 3.0,
    };

    // In-memory follower: pure shipping + apply cost per window size.
    for window in [64usize, 512] {
        suite.run(&format!("catchup_1000_records_window{window}"), cfg, || {
            catch_up(&leader, &base, None, window);
        });
    }

    // Journaled follower: add the local durability cost (fsync every op —
    // the same ack-after-durability contract the leader honors).
    let follower_root = temp_root("follower");
    let mut round = 0u32;
    suite.run("catchup_1000_records_journaled", cfg, || {
        round += 1;
        let name = format!("{MODEL}-{round}");
        let wal = Wal::create_at(
            &follower_root,
            &name,
            &base,
            0,
            FsyncPolicy::EveryOp,
            0,
            b"bench-key".to_vec(),
        )
        .unwrap();
        catch_up(&leader, &base, Some(Arc::new(wal)), 512);
        Wal::remove_dir(&follower_root, &name);
    });

    // Leader-side cut: what one pull_log costs (parse + slice the log).
    for (name, after) in [("pull_window_cold_start", 0u64), ("pull_window_near_head", OPS - 64)] {
        suite.run(name, cfg, || {
            let batch = leader.read_records_after(after, 64);
            assert!(!batch.snapshot_needed);
            std::hint::black_box(batch.records.len());
        });
    }

    // The wire framing itself: encode a shipped record the way pull_log
    // responses do (per-record JSON encode dominates the response path).
    let rec = LogRecord {
        epoch: 1,
        request: Request {
            v: 1,
            model: MODEL.to_string(),
            op: Op::Delete { ids: vec![1, 2, 3] },
        },
    };
    suite.run(
        "encode_shipped_record",
        BenchConfig { target_seconds: 1.0, ..Default::default() },
        || {
            std::hint::black_box(
                dare::coordinator::api::encode_request(&rec.request).to_string(),
            );
        },
    );

    drop(leader);
    let _ = std::fs::remove_dir_all(&leader_root);
    let _ = std::fs::remove_dir_all(&follower_root);
    suite.save_json_to("BENCH_replication.json")?;
    Ok(())
}
