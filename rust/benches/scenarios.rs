//! Bench: scripted workload scenarios (DESIGN.md §14) — the four canonical
//! scenarios (adversarial churn, poison-purge, sliding-window drift,
//! zipf multi-tenant) compiled at `DARE_SCENARIO_SCALE` and replayed
//! against the full coordinator stack under the ambient `DARE_LAZY_POLICY`.
//!
//! Unlike the other benches this one measures *per-op latency
//! distributions*, not ns/iter: every wire round-trip through
//! `UnlearningService::handle` lands in a log-spaced `util::histogram`,
//! and the report carries p50/p95/p99/max per scenario × op type. Each
//! replay is also cross-checked (differential oracle byte-equality,
//! scratch-retrain where applicable, telemetry coherence), so a BENCH run
//! doubles as a correctness pass — numbers from a run that diverged from
//! its oracle never get written.
//!
//! Emits `BENCH_scenarios.json` at the repo root.

use dare::exp::scenarios::{
    cross_check, replay, report_json, save_report, scenario_json, scenario_scale, Scenario,
};

fn main() -> anyhow::Result<()> {
    let scale = scenario_scale();
    let mut entries = Vec::new();
    for sc in Scenario::canonical(scale) {
        let compiled = sc.compile();
        let r = replay(&compiled);
        cross_check(&compiled, &r);
        let entry = scenario_json(&compiled, &r);
        let n_ops: u64 = entry.get("ops_total").and_then(|v| v.as_u64()).unwrap_or(0);
        println!(
            "{:<18} scale={} ops={} wall={:.3}s",
            compiled.name, scale, n_ops, r.wall_s
        );
        for (op, h) in &r.per_op {
            println!(
                "  {:<12} n={:<6} p50={:.6}s p95={:.6}s p99={:.6}s max={:.6}s",
                op,
                h.count(),
                h.p50(),
                h.p95(),
                h.p99(),
                h.max()
            );
        }
        entries.push(entry);
    }
    let report = report_json(scale, entries);
    // Anchor on the manifest so the report lands at the repo root (next to
    // the other BENCH_*.json files and inside CI's artifact glob) no matter
    // where cargo set the working directory.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_scenarios.json");
    save_report(&out, &report)?;
    println!("wrote {}", out.display());
    Ok(())
}
