//! Bench: Table 5 — predictive performance of G-DaRE vs the baseline
//! families across the corpus.

use dare::exp::common::ExpConfig;
use dare::exp::table5;

fn main() {
    let scale = std::env::var("DARE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000usize);
    let cfg = ExpConfig {
        scale_div: scale,
        repeats: 2,
        max_trees: 25,
        out_dir: "results".into(),
        ..Default::default()
    };
    let r = table5::run(&cfg).expect("table5");
    println!("{}", table5::render(&r));
}
