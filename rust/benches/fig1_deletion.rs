//! Bench: Figure 1 / Table 2 — end-to-end deletion efficiency on a
//! representative slice of the corpus, plus per-deletion latency micro-bench.
//! Subtree retrains triggered by threshold invalidation now run through the
//! sort-free training workspace (DESIGN.md §6); the micro suite is mirrored
//! to `BENCH_fig1_deletion.json` at the repo root for cross-PR tracking.
//!
//! Env knobs: DARE_BENCH_SCALE (default 2000), DARE_BENCH_DATASETS
//! (comma list, default ctr,twitter,credit_card), DARE_BENCH_CRITERION.

use dare::bench::{BenchConfig, Suite};
use dare::eval::adversary::Adversary;
use dare::exp::common::ExpConfig;
use dare::exp::{fig1, table2};
use dare::forest::DareForest;
use dare::util::rng::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = env_usize("DARE_BENCH_SCALE", 2000);
    let datasets: Vec<String> = std::env::var("DARE_BENCH_DATASETS")
        .unwrap_or_else(|_| "ctr,twitter,credit_card".into())
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let criterion = std::env::var("DARE_BENCH_CRITERION")
        .unwrap_or_else(|_| "gini".into())
        .parse()
        .unwrap_or(dare::forest::SplitCriterion::Gini);

    // ---- micro: single-deletion latency ---------------------------------
    let mut suite = Suite::new("fig1 deletion");
    let info = dare::data::registry::find(&datasets[0]).expect("dataset");
    let (train, _) = ExpConfig {
        scale_div: scale,
        ..Default::default()
    }
    .prepare(&info, 0);
    let params = dare::forest::Params::gdare(&info.gini);
    let base = DareForest::fit(train, &params, 1);
    let mut rng = Rng::new(2);
    let mut forest = base.clone();
    suite.run(
        &format!("delete one instance [{}]", info.name),
        BenchConfig {
            target_seconds: 2.0,
            max_iters: 400,
            ..Default::default()
        },
        || {
            if forest.n_alive() < 16 {
                forest = base.clone();
            }
            let live = forest.live_ids();
            let id = live[rng.index(live.len())];
            forest.delete_seq(id).unwrap();
        },
    );
    let mut forest2 = base.clone();
    suite.run(
        &format!("delete worst-of-50 instance [{}]", info.name),
        BenchConfig {
            target_seconds: 2.0,
            max_iters: 200,
            ..Default::default()
        },
        || {
            if forest2.n_alive() < 64 {
                forest2 = base.clone();
            }
            let id = Adversary::WorstOf(50)
                .next_target(&forest2, &mut rng)
                .unwrap();
            forest2.delete_seq(id).unwrap();
        },
    );
    suite.save_json().ok();
    let root_json =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_fig1_deletion.json");
    suite.save_json_to(&root_json).ok();

    // ---- end-to-end: the paper's speedup grid on the selected slice -------
    let cfg = ExpConfig {
        scale_div: scale,
        repeats: 1,
        max_deletions: 100,
        worst_of: 50,
        datasets,
        criterion,
        out_dir: "results".into(),
        ..Default::default()
    };
    let r = fig1::run(&cfg).expect("fig1");
    println!("{}", fig1::render(&r));
    let rows = table2::summarize(&r);
    println!("{}", table2::render(&rows, cfg.criterion_tag()));
}
