//! Bench: Table 3 — memory breakdown across the corpus.

use dare::exp::common::ExpConfig;
use dare::exp::table3;

fn main() {
    let scale = std::env::var("DARE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000usize);
    let cfg = ExpConfig {
        scale_div: scale,
        max_trees: 25,
        out_dir: "results".into(),
        ..Default::default()
    };
    let r = table3::run(&cfg).expect("table3");
    println!("{}", table3::render(&r));
}
