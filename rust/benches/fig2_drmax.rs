//! Bench: Figure 2 — d_rmax sweep (deletion efficiency / predictive perf /
//! retrain-depth histogram) on Bank Marketing (paper's headline dataset).

use dare::exp::common::ExpConfig;
use dare::exp::fig2;

fn main() {
    let scale = std::env::var("DARE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000usize);
    let dataset =
        std::env::var("DARE_BENCH_DATASET").unwrap_or_else(|_| "bank_marketing".into());
    let cfg = ExpConfig {
        scale_div: scale,
        repeats: 1,
        max_deletions: 60,
        worst_of: 30,
        max_trees: 25,
        out_dir: "results".into(),
        ..Default::default()
    };
    let r = fig2::run(&cfg, &dataset).expect("fig2");
    println!("{}", fig2::render(&r));
}
