//! Bench: coordinator service throughput — predict QPS, deletion latency
//! through the batcher, and batched vs unbatched deletion streams (§A.7).

use dare::bench::{BenchConfig, Suite};
use dare::coordinator::{ServiceConfig, UnlearningService};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params};
use dare::util::json::parse;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn fresh_service(n: usize) -> std::sync::Arc<UnlearningService> {
    let data = generate(
        &SynthSpec {
            n,
            informative: 4,
            redundant: 2,
            noise: 6,
            flip: 0.05,
            ..Default::default()
        },
        4,
    );
    let forest = DareForest::fit(
        data,
        &Params {
            n_trees: 10,
            max_depth: 10,
            k: 10,
            n_threads: 4,
            ..Default::default()
        },
        8,
    );
    UnlearningService::new(
        forest,
        ServiceConfig {
            batch_window: Duration::from_millis(2),
            use_pjrt: false,
            ..Default::default()
        },
    )
}

fn main() {
    let mut suite = Suite::new("coordinator");
    let quick = BenchConfig {
        target_seconds: 2.0,
        ..Default::default()
    };

    let svc = fresh_service(4000);
    let p = svc.n_features();
    let row = vec!["0.25"; p].join(",");
    let predict_req = parse(&format!(r#"{{"op":"predict","rows":[[{row}]]}}"#)).unwrap();
    suite.run("predict request (native engine)", quick, || {
        let r = svc.handle(&predict_req);
        std::hint::black_box(r.get("ok"));
    });

    let stats_req = parse(r#"{"op":"stats"}"#).unwrap();
    suite.run("stats request", quick, || {
        std::hint::black_box(svc.handle(&stats_req).get("ok"));
    });

    // deletion through the batcher (single-id requests)
    let mut next_id = 0u32;
    suite.run(
        "delete request through batcher",
        BenchConfig {
            target_seconds: 2.0,
            max_iters: 600,
            ..Default::default()
        },
        || {
            let req = parse(&format!(r#"{{"op":"delete","ids":[{next_id}]}}"#)).unwrap();
            std::hint::black_box(svc.handle(&req).get("ok"));
            next_id += 1;
        },
    );

    // §A.7: one batch of 64 vs 64 singles
    let svc_batch = fresh_service(4000);
    let mut base = 0u32;
    suite.run(
        "delete batch of 64 (one request)",
        BenchConfig {
            target_seconds: 3.0,
            min_iters: 5,
            max_iters: 30,
            warmup_iters: 1,
        },
        || {
            let ids: Vec<String> = (base..base + 64).map(|i| i.to_string()).collect();
            let req = parse(&format!(r#"{{"op":"delete","ids":[{}]}}"#, ids.join(","))).unwrap();
            std::hint::black_box(svc_batch.handle(&req).get("ok"));
            base += 64;
        },
    );

    // Sharded read path under write churn: predictions keep flowing while a
    // background thread streams deletions — the scenario the per-shard locks
    // exist for (before sharding, every predict waited on the global write
    // lock for the whole retrain).
    let svc_churn = fresh_service(4000);
    let stop = Arc::new(AtomicBool::new(false));
    let bg = {
        let svc = Arc::clone(&svc_churn);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut id = 0u32;
            while !stop.load(Ordering::Relaxed) {
                let req = parse(&format!(r#"{{"op":"delete","ids":[{id}]}}"#)).unwrap();
                std::hint::black_box(svc.handle(&req).get("ok"));
                id += 1;
            }
        })
    };
    let p = svc_churn.n_features();
    let row = vec!["0.25"; p].join(",");
    let churn_req = parse(&format!(r#"{{"op":"predict","rows":[[{row}]]}}"#)).unwrap();
    suite.run("predict request during delete churn (sharded)", quick, || {
        let r = svc_churn.handle(&churn_req);
        std::hint::black_box(r.get("ok"));
    });
    stop.store(true, Ordering::Relaxed);
    bg.join().unwrap();

    suite.save_json().ok();
}
