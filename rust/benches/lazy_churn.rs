//! Bench: deferred unlearning under churn (ISSUE 4 / DESIGN.md §9) —
//! eager vs on_read vs budgeted across delete/predict interleaving ratios.
//!
//! Each case replays one seeded op stream (deletes + batched predicts at a
//! fixed ratio) against a fresh forest clone under one policy. What to
//! expect: `on_read` wins hardest on delete-heavy streams (retrains are
//! deferred and mostly never read), `budgeted` sits between, and on
//! read-heavy streams the three converge (flush-on-read does the eager
//! work, shifted onto the first reader). Results are exact under every
//! policy, so this bench measures *scheduling*, not model drift.
//!
//! Emits `BENCH_lazy.json` at the repo root (ns/iter per case).

use dare::bench::{BenchConfig, Suite};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, LazyPolicy, Params};
use dare::util::rng::Rng;

fn base_forest() -> DareForest {
    let data = generate(
        &SynthSpec {
            n: 3000,
            informative: 4,
            redundant: 2,
            noise: 6,
            flip: 0.05,
            ..Default::default()
        },
        9,
    );
    DareForest::fit(
        data,
        &Params {
            n_trees: 10,
            max_depth: 10,
            k: 10,
            ..Default::default()
        },
        21,
    )
}

/// Replay `ops` operations at `deletes_per_predict : 1` (or `1 :
/// predicts_per_delete`) against a clone of `base` under `policy`.
fn churn(base: &DareForest, policy: LazyPolicy, deletes: usize, predicts: usize, ops: usize) {
    let mut f = base.clone();
    f.set_lazy_policy(policy);
    let mut rng = Rng::new(0xC0FFEE ^ deletes as u64 ^ (predicts as u64) << 8);
    let probe: Vec<Vec<f32>> = (0..48u32).map(|i| f.data().row(i)).collect();
    let cycle = deletes + predicts;
    for op in 0..ops {
        if op % cycle < deletes {
            let live = f.live_ids();
            let id = live[rng.index(live.len())];
            f.delete_seq(id).unwrap();
        } else {
            // flush-on-read entry point: a no-op flush under eager
            std::hint::black_box(f.predict_proba_rows_flushed(&probe));
        }
    }
    // Every policy ends at the same logical model; leave the backlog
    // standing — draining it is the *next* stream's (or compactor's) cost,
    // which is exactly the scheduling effect being measured.
    std::hint::black_box(f.dirty_subtrees());
}

fn main() -> anyhow::Result<()> {
    let mut suite = Suite::new("lazy");
    let base = base_forest();
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 40,
        target_seconds: 2.0,
    };
    let policies = [
        ("eager", LazyPolicy::Eager),
        ("on_read", LazyPolicy::OnRead),
        ("budgeted4", LazyPolicy::Budgeted(4)),
    ];
    // (name, deletes, predicts) per cycle — delete-heavy to read-heavy
    let mixes = [
        ("del8_pred1", 8usize, 1usize),
        ("del1_pred1", 1, 1),
        ("del1_pred8", 1, 8),
    ];
    for (pname, policy) in policies {
        for (mname, d, p) in mixes {
            suite.run(&format!("churn_{mname}_{pname}"), cfg, || {
                churn(&base, policy, d, p, 180);
            });
        }
    }
    // The drain itself, in isolation: mark 120 deletions, then flush-all.
    suite.run("flush_all_after_120_marks", cfg, || {
        let mut f = base.clone();
        f.set_lazy_policy(LazyPolicy::OnRead);
        let mut rng = Rng::new(7);
        for _ in 0..120 {
            let live = f.live_ids();
            let id = live[rng.index(live.len())];
            f.delete_seq(id).unwrap();
        }
        std::hint::black_box(f.flush_all());
    });
    suite.save_json_to("BENCH_lazy.json")?;
    Ok(())
}
