//! Bench: L3 hot paths — split-criterion scoring, threshold enumeration,
//! node training, single-tree deletion, prediction. The profiling anchors
//! for EXPERIMENTS.md §Perf.

use dare::bench::{BenchConfig, Suite};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::criterion::{entropy, gini};
use dare::forest::stats::enumerate_valid;
use dare::forest::tree::DareTree;
use dare::forest::Params;
use dare::util::rng::Rng;

fn main() {
    let mut suite = Suite::new("hot paths");
    let quick = BenchConfig {
        target_seconds: 1.5,
        ..Default::default()
    };

    // --- criterion scoring (the L1 kernel's native twin) -------------------
    let mut rng = Rng::new(1);
    let counts: Vec<(u32, u32, u32, u32)> = (0..8192)
        .map(|_| {
            let n = 2 + rng.index(100_000) as u32;
            let np = rng.index(n as usize) as u32;
            let nl = 1 + rng.index(n as usize - 1) as u32;
            let nlp = np.min(nl);
            (n, np, nl, nlp)
        })
        .collect();
    suite.run("gini x8192 (native)", quick, || {
        let mut acc = 0.0;
        for &(n, np, nl, nlp) in &counts {
            acc += gini(n, np, nl, nlp);
        }
        std::hint::black_box(acc);
    });
    suite.run("entropy x8192 (native)", quick, || {
        let mut acc = 0.0;
        for &(n, np, nl, nlp) in &counts {
            acc += entropy(n, np, nl, nlp);
        }
        std::hint::black_box(acc);
    });

    // --- valid-threshold enumeration (the training/resampling inner loop) --
    let mut pairs: Vec<(f32, u8)> = (0..4096)
        .map(|_| (rng.range_f32(-10.0, 10.0), rng.bernoulli(0.4) as u8))
        .collect();
    suite.run("enumerate_valid n=4096", quick, || {
        let mut p = pairs.clone();
        std::hint::black_box(enumerate_valid(&mut p).len());
    });
    pairs.truncate(256);
    suite.run("enumerate_valid n=256", quick, || {
        let mut p = pairs.clone();
        std::hint::black_box(enumerate_valid(&mut p).len());
    });

    // --- single-tree operations -------------------------------------------
    let data = generate(
        &SynthSpec {
            n: 4000,
            informative: 5,
            redundant: 3,
            noise: 8,
            flip: 0.05,
            ..Default::default()
        },
        3,
    );
    let params = Params {
        n_trees: 1,
        max_depth: 12,
        k: 10,
        ..Default::default()
    };
    suite.run("DareTree::fit n=4000 p=16 d=12", BenchConfig {
        target_seconds: 3.0,
        min_iters: 5,
        max_iters: 50,
        warmup_iters: 1,
    }, || {
        std::hint::black_box(DareTree::fit(&data, &params, 7).shape());
    });

    let tree = DareTree::fit(&data, &params, 7);
    let rows: Vec<Vec<f32>> = (0..256).map(|i| data.row(i)).collect();
    suite.run("DareTree::predict x256", quick, || {
        let mut acc = 0.0f32;
        for r in &rows {
            acc += tree.predict(r);
        }
        std::hint::black_box(acc);
    });

    let mut del_data = data.clone();
    let mut del_tree = tree.clone();
    let mut i = 0u32;
    suite.run("DareTree::delete (sequential ids)", BenchConfig {
        target_seconds: 2.0,
        max_iters: 2000,
        ..Default::default()
    }, || {
        if del_data.n_alive() < 256 {
            del_data = data.clone();
            del_tree = tree.clone();
            i = 0;
        }
        while !del_data.is_alive(i) {
            i += 1;
        }
        del_tree.delete(&del_data, &params, i);
        del_data.mark_removed(i);
        i += 1;
    });

    suite.save_json().ok();
}
