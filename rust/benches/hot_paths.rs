//! Bench: L3 hot paths — split-criterion scoring, threshold enumeration,
//! node training (seed gather+sort path vs. sort-free workspace), single-tree
//! deletion, prediction. The profiling anchors for the perf trajectory:
//! besides the human-readable report this emits `BENCH_hot_paths.json` at the
//! repo root (suite name + ns/iter per case) so future PRs can diff perf.

use dare::bench::{BenchConfig, Suite};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::criterion::{entropy, gini};
use dare::forest::stats::{enumerate_valid, enumerate_valid_presorted};
use dare::forest::train::{train, TrainCtx, ROOT_PATH};
use dare::forest::tree::DareTree;
use dare::forest::workspace::train_subtree;
use dare::forest::Params;
use dare::util::rng::Rng;

fn main() {
    let mut suite = Suite::new("hot paths");
    let quick = BenchConfig {
        target_seconds: 1.5,
        ..Default::default()
    };

    // --- criterion scoring (the L1 kernel's native twin) -------------------
    let mut rng = Rng::new(1);
    let counts: Vec<(u32, u32, u32, u32)> = (0..8192)
        .map(|_| {
            let n = 2 + rng.index(100_000) as u32;
            let np = rng.index(n as usize) as u32;
            let nl = 1 + rng.index(n as usize - 1) as u32;
            let nlp = np.min(nl);
            (n, np, nl, nlp)
        })
        .collect();
    suite.run("gini x8192 (native)", quick, || {
        let mut acc = 0.0;
        for &(n, np, nl, nlp) in &counts {
            acc += gini(n, np, nl, nlp);
        }
        std::hint::black_box(acc);
    });
    suite.run("entropy x8192 (native)", quick, || {
        let mut acc = 0.0;
        for &(n, np, nl, nlp) in &counts {
            acc += entropy(n, np, nl, nlp);
        }
        std::hint::black_box(acc);
    });

    // --- valid-threshold enumeration (the training/resampling inner loop) --
    let mut pairs: Vec<(f32, u8)> = (0..4096)
        .map(|_| (rng.range_f32(-10.0, 10.0), rng.bernoulli(0.4) as u8))
        .collect();
    suite.run("enumerate_valid n=4096", quick, || {
        let mut p = pairs.clone();
        std::hint::black_box(enumerate_valid(&mut p).len());
    });
    // the workspace's linear-scan twin over an already-sorted run
    let scan_col: Vec<f32> = pairs.iter().map(|&(v, _)| v).collect();
    let scan_labels: Vec<u8> = pairs.iter().map(|&(_, y)| y).collect();
    let mut scan_run: Vec<u32> = (0..4096u32).collect();
    scan_run.sort_unstable_by(|&a, &b| scan_col[a as usize].total_cmp(&scan_col[b as usize]));
    suite.run("enumerate_valid_presorted n=4096", quick, || {
        std::hint::black_box(
            enumerate_valid_presorted(&scan_col, &scan_labels, &scan_run).len(),
        );
    });
    pairs.truncate(256);
    suite.run("enumerate_valid n=256", quick, || {
        let mut p = pairs.clone();
        std::hint::black_box(enumerate_valid(&mut p).len());
    });

    // --- single-tree operations -------------------------------------------
    // n=4096 synthetic case: the acceptance anchor for node training and
    // single-tree deletion.
    let data = generate(
        &SynthSpec {
            n: 4096,
            informative: 5,
            redundant: 3,
            noise: 8,
            flip: 0.05,
            ..Default::default()
        },
        3,
    );
    let params = Params {
        n_trees: 1,
        max_depth: 12,
        k: 10,
        ..Default::default()
    };
    let fit_cfg = BenchConfig {
        target_seconds: 3.0,
        min_iters: 5,
        max_iters: 50,
        warmup_iters: 1,
    };
    // head-to-head: seed gather+sort path vs. the sort-free workspace
    // (bit-exact results; see tests/workspace_exactness.rs)
    suite.run("train seed-path n=4096 p=16 d=12", fit_cfg, || {
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 7,
        };
        std::hint::black_box(train(&ctx, data.live_ids(), 0, ROOT_PATH).shape());
    });
    suite.run("train workspace n=4096 p=16 d=12", fit_cfg, || {
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 7,
        };
        std::hint::black_box(train_subtree(&ctx, data.live_ids(), 0, ROOT_PATH).shape());
    });
    suite.run("DareTree::fit n=4096 p=16 d=12", fit_cfg, || {
        std::hint::black_box(DareTree::fit(&data, &params, 7).shape());
    });

    let tree = DareTree::fit(&data, &params, 7);
    let rows: Vec<Vec<f32>> = (0..256).map(|i| data.row(i)).collect();
    suite.run("DareTree::predict x256", quick, || {
        let mut acc = 0.0f32;
        for r in &rows {
            acc += tree.predict(r);
        }
        std::hint::black_box(acc);
    });

    let mut del_data = data.clone();
    let mut del_tree = tree.clone();
    let mut i = 0u32;
    suite.run("DareTree::delete (sequential ids)", BenchConfig {
        target_seconds: 2.0,
        max_iters: 2000,
        ..Default::default()
    }, || {
        if del_data.n_alive() < 256 {
            del_data = data.clone();
            del_tree = tree.clone();
            i = 0;
        }
        while !del_data.is_alive(i) {
            i += 1;
        }
        del_tree.delete(&del_data, &params, i);
        del_data.mark_removed(i);
        i += 1;
    });

    suite.save_json().ok();
    // machine-readable perf trajectory at the repo root (CARGO_MANIFEST_DIR
    // is rust/, so ".." is the repo root regardless of the bench's cwd)
    let root_json =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hot_paths.json");
    if let Err(e) = suite.save_json_to(&root_json) {
        eprintln!("warning: could not write {}: {e}", root_json.display());
    } else {
        println!("wrote {}", root_json.display());
    }
}
