//! Bench: Table 7 — G-DaRE training time across the corpus; also compares
//! DaRE training against the lean standard-RF baseline (Theorem 3.2: the
//! statistics overhead should be a small constant factor). Forest fitting
//! now runs through the sort-free training workspace (DESIGN.md §6); the
//! micro suite is mirrored to `BENCH_table7_train.json` at the repo root
//! for cross-PR perf tracking.

use dare::baselines::simple::{BaselineForest, BaselineParams};
use dare::bench::{BenchConfig, Suite};
use dare::exp::common::ExpConfig;
use dare::exp::table7;
use dare::forest::DareForest;

fn main() {
    let scale = std::env::var("DARE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000usize);
    let cfg = ExpConfig {
        scale_div: scale,
        repeats: 2,
        max_trees: 25,
        out_dir: "results".into(),
        ..Default::default()
    };
    let r = table7::run(&cfg).expect("table7");
    println!("{}", table7::render(&r));

    // micro: DaRE vs lean-RF training cost on one dataset
    let info = dare::data::registry::find("twitter").unwrap();
    let (train, _) = cfg.prepare(&info, 0);
    let pp = cfg.paper_params(&info);
    let params = cfg.params(&pp, 0);
    let mut suite = Suite::new("table7 train micro");
    let bc = BenchConfig {
        target_seconds: 3.0,
        max_iters: 20,
        min_iters: 5,
        warmup_iters: 1,
    };
    suite.run("DaRE fit [twitter]", bc, || {
        let f = DareForest::fit(train.clone(), &params, 1);
        std::hint::black_box(f.n_trees());
    });
    let bp = BaselineParams {
        n_trees: params.n_trees,
        max_depth: params.max_depth,
        n_threads: params.n_threads,
        ..Default::default()
    };
    suite.run("lean standard-RF fit [twitter]", bc, || {
        let f = BaselineForest::fit(&train, &bp, 1);
        std::hint::black_box(f.n_trees());
    });
    suite.save_json().ok();
    let root_json =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_table7_train.json");
    suite.save_json_to(&root_json).ok();
}
