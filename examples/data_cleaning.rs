//! Dataset cleaning (paper §6 "Dataset Cleaning"): poison a fraction of the
//! training labels, watch the model degrade, then *unlearn* exactly the
//! poisoned instances — without retraining from scratch — and watch the
//! metric recover. The cleanup itself is filed as ONE batched deletion
//! through the typed wire client (`Client::delete`, DESIGN.md §10), the
//! way a production incident-response job would do it.
//!
//!     cargo run --release --offline --example data_cleaning

use dare::coordinator::{serve, Client, ServiceConfig, UnlearningService, DEFAULT_MODEL};
use dare::data::registry::find;
use dare::data::split::train_test;
use dare::forest::{DareForest, Params};
use dare::util::rng::Rng;
use dare::util::timer::time;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let info = find("twitter").expect("corpus dataset");
    let data = info.generate(500, 11);
    let (train, test) = train_test(&data, 0.8, 11);
    let (_, test_ys, _) = test.to_row_major();

    // --- targeted label-flip poisoning --------------------------------------
    // Flip a large slice of *positive* labels to negative (a class-skew
    // attack): this reliably biases the model toward the negative class,
    // unlike random flips which mostly wash out as noise.
    let mut rng = Rng::new(5);
    let live = train.live_ids();
    let mut rows = Vec::with_capacity(live.len());
    let mut labels = Vec::with_capacity(live.len());
    for &id in &live {
        rows.push(train.row(id));
        labels.push(train.y(id));
    }
    let positives: Vec<usize> = (0..labels.len()).filter(|&i| labels[i] == 1).collect();
    let n_poison = positives.len() / 2; // flip half the positives
    let mut poisoned_ids = Vec::with_capacity(n_poison);
    for &pi in rng.sample_indices(positives.len(), n_poison).iter() {
        let i = positives[pi];
        labels[i] = 0;
        poisoned_ids.push(i as u32); // ids in the rebuilt dataset = position
    }
    let poisoned_train = dare::data::Dataset::from_rows(&rows, labels);

    let params = Params::gdare(&info.gini).with_threads(4);

    // --- clean model (upper bound) ------------------------------------------
    let clean = DareForest::fit(train.clone(), &params, 21);
    let clean_score = info
        .metric
        .score(&clean.predict_proba_dataset(&test), &test_ys);

    // --- poisoned model, served ----------------------------------------------
    let (forest, fit_secs) = time(|| DareForest::fit(poisoned_train, &params, 21));
    let poisoned_score = info
        .metric
        .score(&forest.predict_proba_dataset(&test), &test_ys);
    println!(
        "clean {m}: {clean_score:.4} | poisoned ({n_poison} labels flipped) {m}: {poisoned_score:.4} | fit {fit_secs:.2}s",
        m = info.metric.name()
    );
    let svc = UnlearningService::new(forest, ServiceConfig::default());
    let svc_srv = Arc::clone(&svc);
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(svc_srv, "127.0.0.1:0", 2, move |a| {
            tx.send(a).unwrap();
        })
    });
    let addr = rx.recv()?;

    // --- unlearn the poison: one typed batched wire request ------------------
    let mut client = Client::connect(addr)?;
    let (out, del_secs) = time(|| client.delete(DEFAULT_MODEL, &poisoned_ids));
    let out = out?;
    println!(
        "unlearned {} poisoned instances in {del_secs:.2}s ({:.1}ms each; retrain cost {} instances)",
        out.deleted,
        1000.0 * del_secs / out.deleted.max(1) as f64,
        out.retrain_cost
    );
    client.shutdown()?;
    server.join().unwrap()?;

    // the served model after cleanup (snapshot flushes any deferred work)
    let cleaned = svc.snapshot_forest();
    let cleaned_score = info
        .metric
        .score(&cleaned.predict_proba_dataset(&test), &test_ys);
    println!(
        "{m} after cleaning: {cleaned_score:.4} (clean model {clean_score:.4}, poisoned {poisoned_score:.4})",
        m = info.metric.name()
    );

    // the cleaned model should recover most of the poisoning damage
    let recovered = (cleaned_score - poisoned_score) / (clean_score - poisoned_score).max(1e-9);
    println!("recovered {:.0}% of the poisoning damage", 100.0 * recovered.clamp(0.0, 1.0));
    Ok(())
}
