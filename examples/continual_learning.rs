//! Continual learning (paper §6): a streaming workload that *adds* new
//! observations and *removes* stale ones, keeping the model current without
//! ever retraining from scratch. The streamed model is then installed in
//! the serving registry and inspected through the typed wire client
//! (`Client::stats` / `Client::add` / `Client::delete_cost`, DESIGN.md §10).
//!
//!     cargo run --release --offline --example continual_learning

use dare::coordinator::{serve, Client, ServiceConfig, UnlearningService, DEFAULT_MODEL};
use dare::data::registry::find;
use dare::data::split::train_test;
use dare::forest::{DareForest, Params};
use dare::util::json::Value;
use dare::util::rng::Rng;
use dare::util::timer::Stopwatch;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let info = find("synthetic").expect("corpus dataset");
    let data = info.generate(2000, 13); // 1/2000 of 1M = 800 rows
    let (train, test) = train_test(&data, 0.7, 13);
    let (_, test_ys, _) = test.to_row_major();
    // a reserve pool to stream in (same distribution)
    let pool = info.generate(2000, 14);

    let params = Params {
        n_trees: 25,
        max_depth: 10,
        k: 10,
        d_rmax: 2,
        n_threads: 4,
        ..Default::default()
    };
    let mut forest = DareForest::fit(train, &params, 31);
    let acc0 = info
        .metric
        .score(&forest.predict_proba_dataset(&test), &test_ys);
    println!(
        "initial window: {} instances, test acc {acc0:.4}",
        forest.n_alive()
    );

    // --- sliding-window stream: 300 steps of add-one / delete-oldest ------
    let mut rng = Rng::new(9);
    let mut sw_add = Stopwatch::new();
    let mut sw_del = Stopwatch::new();
    let mut window: std::collections::VecDeque<u32> = forest.live_ids().into();
    let mut added = 0usize;
    for step in 0..300 {
        // add a fresh observation from the pool
        let src = rng.index(pool.n_total());
        sw_add.start();
        let id = forest.add(&pool.row(src as u32), pool.y(src as u32));
        sw_add.stop();
        window.push_back(id);
        added += 1;
        // retire the oldest
        if let Some(old) = window.pop_front() {
            sw_del.start();
            forest.delete(old)?;
            sw_del.stop();
        }
        if step % 100 == 99 {
            let acc = info
                .metric
                .score(&forest.predict_proba_dataset(&test), &test_ys);
            println!(
                "step {:>3}: window {} | acc {acc:.4} | add {:.2}ms | delete {:.2}ms",
                step + 1,
                forest.n_alive(),
                1000.0 * sw_add.seconds() / added as f64,
                1000.0 * sw_del.seconds() / added as f64,
            );
        }
    }

    let acc_end = info
        .metric
        .score(&forest.predict_proba_dataset(&test), &test_ys);
    println!(
        "after 300 add+delete cycles: acc {acc_end:.4} (start {acc0:.4}); window size steady at {}",
        forest.n_alive()
    );
    // the model must stay healthy through the stream
    assert!(acc_end > acc0 - 0.08, "accuracy collapsed during streaming");
    println!("continual-learning stream complete");

    // --- serve the streamed model and keep streaming over the wire ----------
    let svc = UnlearningService::new(forest, ServiceConfig::default());
    let svc_srv = Arc::clone(&svc);
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(svc_srv, "127.0.0.1:0", 2, move |a| {
            tx.send(a).unwrap();
        })
    });
    let addr = rx.recv()?;
    let mut client = Client::connect(addr)?;
    // one more window slide, now through the typed client
    let src = rng.index(pool.n_total());
    let fresh = client.add(DEFAULT_MODEL, &pool.row(src as u32), pool.y(src as u32))?;
    let oldest = window.pop_front().expect("window is non-empty");
    println!(
        "wire slide: +{fresh}, -{oldest} (dry-run cost {} instances)",
        client.delete_cost(DEFAULT_MODEL, oldest)?
    );
    client.delete(DEFAULT_MODEL, &[oldest])?;
    let stats = client.stats(DEFAULT_MODEL)?;
    println!(
        "served window: {} live instances across {} trees ({} shards)",
        stats.get("n_alive").and_then(Value::as_u64).unwrap_or(0),
        stats.get("n_trees").and_then(Value::as_u64).unwrap_or(0),
        stats.get("n_shards").and_then(Value::as_u64).unwrap_or(0),
    );
    client.shutdown()?;
    server.join().unwrap()?;
    Ok(())
}
