//! Multi-tenant serving: several models behind ONE coordinator, managed
//! over the typed, versioned wire API (DESIGN.md §10) — per-tenant GDPR
//! deletion with hard isolation, lifecycle ops (`create` / `save` /
//! `drop` / `load`) and per-model stats, all through the typed client.
//!
//!     cargo run --release --offline --example multi_tenant

use dare::coordinator::{
    serve, ApiError, Client, CreateSpec, ServiceConfig, UnlearningService,
};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params};
use std::sync::Arc;

fn tenant_forest(n: usize, seed: u64) -> DareForest {
    let data = generate(
        &SynthSpec {
            n,
            informative: 4,
            redundant: 1,
            noise: 2,
            flip: 0.05,
            ..Default::default()
        },
        seed,
    );
    DareForest::fit(
        data,
        &Params {
            n_trees: 10,
            max_depth: 8,
            k: 10,
            n_threads: 4,
            ..Default::default()
        },
        seed ^ 0xDA2E,
    )
}

fn main() -> anyhow::Result<()> {
    // Two tenants at startup; a third is created over the wire below.
    println!("training two tenant models...");
    let svc = UnlearningService::with_models(
        vec![
            ("eu-prod".to_string(), tenant_forest(1200, 7)),
            ("us-prod".to_string(), tenant_forest(900, 8)),
        ],
        ServiceConfig::default(),
    );
    let svc_srv = Arc::clone(&svc);
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(svc_srv, "127.0.0.1:0", 4, move |a| {
            tx.send(a).unwrap();
        })
    });
    let addr = rx.recv()?;
    println!("registry service up at {addr}");
    let mut client = Client::connect(addr)?;

    // --- lifecycle: create a third tenant from a corpus dataset ref ---------
    client.create(
        "staging",
        CreateSpec {
            dataset: "twitter".to_string(),
            scale_div: 2000,
            seed: 5,
            n_trees: Some(5),
            max_depth: Some(6),
            k: Some(5),
            ..Default::default()
        },
    )?;
    println!("tenants:");
    for m in client.list()? {
        println!(
            "  {:<10} {} trees, {} live instances, {} shards, policy {}",
            m.name, m.n_trees, m.n_alive, m.n_shards, m.lazy_policy
        );
    }

    // --- isolation: a GDPR purge in us-prod cannot move eu-prod -------------
    let eu_probe = vec![0.1f32; svc.registry().get("eu-prod")?.n_features()];
    let before = client.predict("eu-prod", &[eu_probe.clone()])?;
    let purged = client.delete("us-prod", &(100..160u32).collect::<Vec<_>>())?;
    let after = client.predict("eu-prod", &[eu_probe])?;
    assert_eq!(before, after, "tenant isolation violated");
    println!(
        "us-prod purge: {} erased (retrain cost {}); eu-prod prediction bit-identical {:.6} == {:.6}",
        purged.deleted, purged.retrain_cost, before.probs[0], after.probs[0]
    );

    // --- per-tenant stats ----------------------------------------------------
    let stats = client.stats("us-prod")?;
    println!(
        "us-prod after purge: {} live instances",
        stats.get("n_alive").and_then(dare::util::json::Value::as_u64).unwrap_or(0)
    );

    // --- save / drop / load: park the staging tenant and bring it back ------
    let path = std::env::temp_dir().join("dare_multi_tenant_staging.json");
    client.save("staging", &path.display().to_string())?;
    client.drop_model("staging")?;
    match client.stats("staging") {
        Err(ApiError::UnknownModel(name)) => {
            println!("dropped tenant '{name}' is gone (typed unknown_model error)")
        }
        other => anyhow::bail!("expected UnknownModel, got {other:?}"),
    }
    client.load("staging", &path.display().to_string())?;
    println!(
        "staging restored: {} tenants registered",
        client.list()?.len()
    );
    std::fs::remove_file(&path).ok();

    client.shutdown()?;
    server.join().unwrap()?;
    println!("multi-tenant service stopped cleanly");
    Ok(())
}
