//! End-to-end driver (EXPERIMENTS.md headline run): the full system on a
//! real small workload, proving all layers compose.
//!
//! Pipeline:
//!   1. generate a corpus dataset (paper's Bank Marketing recipe, scaled);
//!   2. tune d_rmax with the paper's tolerance protocol (eval::tuner stage 2);
//!   3. train G-DaRE and R-DaRE; evaluate through the PJRT predictor
//!      (L1/L2 artifacts) when the model fits the compiled shape;
//!   4. start the coordinator and stream GDPR deletion requests through the
//!      typed v1 wire client (DESIGN.md §10), interleaved with predicts;
//!   5. report the speedup vs naive retraining, the R-DaRE error delta, and
//!      the service telemetry.
//!
//!     make artifacts && cargo run --release --offline --example end_to_end

use dare::coordinator::{serve, Client, ServiceConfig, UnlearningService, DEFAULT_MODEL};
use dare::data::registry::find;
use dare::data::split::train_test;
use dare::eval::adversary::Adversary;
use dare::eval::speedup::{measure, SpeedupConfig};
use dare::forest::{DareForest, Params};
use dare::util::json::Value;
use dare::util::timer::time;

fn main() -> anyhow::Result<()> {
    let scale = std::env::var("DARE_E2E_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100usize);
    let info = find("bank_marketing").expect("corpus dataset");
    let data = info.generate(scale, 7);
    let (train, test) = train_test(&data, 0.8, 7);
    let (_, test_ys, _) = test.to_row_major();
    println!(
        "== DaRE end-to-end: bank_marketing @ 1/{scale} scale ({} train / {} test, p={}) ==",
        train.n_total(),
        test.n_total(),
        train.n_features()
    );

    // --- stage 1: models ---------------------------------------------------
    let gdare = Params::gdare(&info.gini).with_threads(4);
    let rdare = Params::rdare(&info.gini, 1).with_threads(4); // tol = 0.25%

    // --- stage 2: deletion-efficiency measurement (paper Fig. 1 protocol) --
    for (name, params) in [("G-DaRE", &gdare), ("R-DaRE(0.25%)", &rdare)] {
        let r = measure(
            &train,
            &test,
            params,
            &SpeedupConfig {
                adversary: Adversary::Random,
                max_deletions: 300,
                metric: info.metric,
                seed: 3,
            },
        );
        println!(
            "{name}: naive retrain {:.2}s | {} deletions in {:.2}s ({:.1}ms each) | speedup {:.0}x{} | {}: {:.4} -> {:.4}",
            r.naive_seconds,
            r.n_deleted,
            r.delete_seconds,
            1000.0 * r.mean_delete_seconds,
            r.speedup,
            if r.extrapolated { " (extrapolated)" } else { "" },
            info.metric.name(),
            r.metric_before,
            r.metric_after,
        );
    }

    // --- stage 3: serve through the coordinator -----------------------------
    let (forest, fit_secs) = time(|| DareForest::fit(train.clone(), &gdare, 42));
    println!("serving a fresh G-DaRE model (fit {fit_secs:.2}s)");
    let svc = UnlearningService::new(forest, ServiceConfig::default());
    println!("PJRT predictor active: {}", svc.pjrt_active());
    let svc_for_server = std::sync::Arc::clone(&svc);
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(svc_for_server, "127.0.0.1:0", 4, move |addr| {
            tx.send(addr).unwrap();
        })
    });
    let addr = rx.recv()?;
    let mut client = Client::connect(addr)?;

    // stream: delete 120 training instances in batches of 6, predicting the
    // test head between batches and tracking the metric trajectory.
    let victims: Vec<u32> = svc.sharded().live_ids().into_iter().take(120).collect();
    let probe_rows: Vec<Vec<f32>> = test.live_ids().iter().take(64).map(|&i| test.row(i)).collect();
    let probe_ys: Vec<u8> = test.live_ids().iter().take(64).map(|&i| test.y(i)).collect();
    let mut curve: Vec<(usize, f64)> = Vec::new();
    for (i, chunk) in victims.chunks(6).enumerate() {
        let out = client.delete(DEFAULT_MODEL, chunk)?;
        anyhow::ensure!(out.deleted == chunk.len(), "a victim id was already gone");
        if i % 5 == 0 {
            let pred = client.predict(DEFAULT_MODEL, &probe_rows)?;
            let acc = dare::metrics::accuracy(&pred.probs, &probe_ys);
            curve.push(((i + 1) * 6, acc));
        }
    }
    println!("probe-accuracy curve over the deletion stream:");
    for (deleted, acc) in &curve {
        println!("  after {deleted:>4} deletions: probe acc {acc:.4}");
    }

    let stats = client.stats(DEFAULT_MODEL)?;
    println!(
        "service telemetry: {}",
        stats.get("telemetry").map(Value::to_string).unwrap_or_default()
    );
    println!(
        "live instances now: {}",
        stats.get("n_alive").and_then(Value::as_u64).unwrap_or(0)
    );
    client.shutdown()?;
    server.join().unwrap()?;

    // --- stage 4: closing check against a scratch model --------------------
    let reduced = svc.sharded().with_data(|d| d.compacted());
    let scratch = DareForest::fit(reduced, &gdare, 99);
    let probs = scratch.predict_proba_dataset(&test);
    let scratch_acc = info.metric.score(&probs, &test_ys);
    let served = svc.snapshot_forest();
    let probs = served.predict_proba_dataset(&test);
    let served_acc = info.metric.score(&probs, &test_ys);
    println!(
        "final: unlearned-model {} = {served_acc:.4} vs scratch-retrained {} = {scratch_acc:.4} (Δ {:+.4})",
        info.metric.name(),
        info.metric.name(),
        served_acc - scratch_acc
    );
    println!("== end-to-end complete ==");
    Ok(())
}
