//! GDPR deletion service demo: run the coordinator, then simulate a fleet
//! of clients filing right-to-be-forgotten requests concurrently while
//! others query predictions — the vLLM-router-style serving view of DaRE,
//! driven entirely through the typed v1 client (`Client::delete` /
//! `Client::predict` / `Client::stats`, DESIGN.md §10). The service runs
//! durably (DESIGN.md §11): every deletion is journaled to a write-ahead
//! log before it's acked, and each one can be receipted with a signed
//! deletion certificate (`Client::certify` / `Client::verify_cert`) that
//! stays verifiable for the lifetime of the signing key. A read-only
//! follower then bootstraps from the leader and tails its log
//! (DESIGN.md §12): the leader's certificate verifies on it, and it
//! refuses mutations with a redirect to the leader.
//!
//!     make artifacts && cargo run --release --offline --example gdpr_service

use dare::coordinator::{
    bootstrap_follower, serve, ApiError, Client, ReplicationConfig, ServiceConfig,
    UnlearningService, DEFAULT_MODEL,
};
use dare::data::registry::find;
use dare::forest::{DareForest, LazyPolicy, Params};
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let info = find("adult").expect("corpus dataset");
    let data = info.generate(500, 3);
    let params = Params::gdare(&info.gini).with_threads(4);
    println!("training the served model ({} instances)...", data.n_total());
    let forest = DareForest::fit(data, &params, 17);

    // Deferred unlearning (DESIGN.md §9): under `on_read`, a deletion
    // returns after updating node statistics — the subtree retrains run
    // when a query reads them (flush-on-read, bit-identical results) or
    // when the background compactor drains them during idle ticks. Set
    // DARE_LAZY_POLICY=eager|on_read|budgeted:<k> to experiment; deletion
    // latency drops under churn while every served bit stays exact.
    let lazy = LazyPolicy::from_env();
    // Event-sourced durability (DESIGN.md §11): with `wal_dir` set, every
    // mutation is appended + fsync'd to a per-model op log before it's
    // acked; a crashed process replays the log on restart and lands on the
    // byte-identical forest. The demo uses a throwaway dir.
    let wal_root = std::env::temp_dir().join(format!("dare-gdpr-demo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&wal_root);
    let svc = UnlearningService::new(
        forest,
        ServiceConfig {
            batch_window: Duration::from_millis(25), // group concurrent requests
            lazy,
            wal_dir: Some(wal_root.clone()),
            cert_key: Some("gdpr-demo-signing-key".to_string()),
            ..Default::default()
        },
    );
    println!("PJRT predictor active: {}", svc.pjrt_active());
    println!("deferral policy: {}", svc.lazy_policy());

    let svc_srv = Arc::clone(&svc);
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(svc_srv, "127.0.0.1:0", 8, move |a| {
            tx.send(a).unwrap();
        })
    });
    let addr = rx.recv()?;
    println!("service up at {addr}");

    // --- 6 deletion clients + 2 prediction clients, concurrently ------------
    let mut handles = Vec::new();
    for c in 0..6u32 {
        handles.push(std::thread::spawn(move || -> anyhow::Result<(usize, usize)> {
            let mut client = Client::connect(addr)?;
            let mut deleted = 0;
            let mut batched = 0;
            for r in 0..10u32 {
                let id = 100 + c * 40 + r;
                // typed right-to-be-forgotten request: the outcome says how
                // many ids landed and whether the server's batcher grouped
                // this request with concurrent ones
                let out = client.delete(DEFAULT_MODEL, &[id])?;
                deleted += out.deleted;
                if out.batch_size > 1 {
                    batched += 1;
                }
            }
            Ok((deleted, batched))
        }));
    }
    let p = svc.n_features();
    for _ in 0..2 {
        handles.push(std::thread::spawn(move || -> anyhow::Result<(usize, usize)> {
            let mut client = Client::connect(addr)?;
            let row = vec![0.0f32; p];
            let mut ok = 0;
            for _ in 0..20 {
                let pred = client.predict(DEFAULT_MODEL, &[row.clone()])?;
                if pred.probs.len() == 1 {
                    ok += 1;
                }
            }
            Ok((ok, 0))
        }));
    }

    let mut total_deleted = 0;
    let mut total_batched = 0;
    for h in handles {
        let (a, b) = h.join().unwrap()?;
        total_deleted += a;
        total_batched += b;
    }
    println!("fleet done: {total_deleted} instances deleted; {total_batched} requests shared a batch");

    let mut client = Client::connect(addr)?;
    let stats = client.stats(DEFAULT_MODEL)?;
    let tele = stats.get("telemetry").unwrap();
    println!("telemetry snapshot:\n{}", tele.to_pretty());
    println!(
        "n_alive = {}",
        stats.get("n_alive").and_then(dare::util::json::Value::as_u64).unwrap_or(0)
    );
    println!(
        "deferred retrains: {} total, {} still pending (policy {})",
        stats.get("deferred_retrains").and_then(dare::util::json::Value::as_u64).unwrap_or(0),
        stats.get("dirty_subtrees").and_then(dare::util::json::Value::as_u64).unwrap_or(0),
        stats.get("lazy_policy").and_then(dare::util::json::Value::as_str).unwrap_or("?"),
    );
    println!(
        "durable: {} (wal epoch {})",
        stats.get("durable").and_then(dare::util::json::Value::as_bool).unwrap_or(false),
        stats.get("wal_epoch").and_then(dare::util::json::Value::as_u64).unwrap_or(0),
    );

    // --- signed deletion certificate for one of the fleet's deletions -------
    // `certify` receipts an already-deleted instance: the HMAC covers
    // {model, id, wal epoch, snapshot hash}, so the data subject (or an
    // auditor) can later ask any holder of the key to `verify_cert` it —
    // including after the model itself is gone.
    let cert = client.certify(DEFAULT_MODEL, 100)?;
    println!(
        "deletion certificate: instance {} @ epoch {} (snapshot {}…, hmac {}…)",
        cert.instance_id,
        cert.epoch,
        &cert.snapshot_hash[..12],
        &cert.hmac[..12],
    );
    println!("certificate verifies: {}", client.verify_cert(&cert)?);
    let mut forged = cert.clone();
    forged.instance_id = 101;
    println!("forged certificate verifies: {}", client.verify_cert(&forged)?);

    // --- read-only follower tailing the leader's WAL (DESIGN.md §12) --------
    // A second service bootstraps every model from the leader's snapshot
    // and tails its op log over the wire. After catch-up it serves the
    // same bytes the leader does: leader-minted certificates verify on it
    // (shared signing key), reads answer at its replicated epoch, and
    // mutations are refused with the stable `read_only` code plus a
    // redirect to the leader.
    let follower_root =
        std::env::temp_dir().join(format!("dare-gdpr-follower-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&follower_root);
    let fsvc = UnlearningService::with_models(
        Vec::new(),
        ServiceConfig {
            wal_dir: Some(follower_root.clone()),
            cert_key: Some("gdpr-demo-signing-key".to_string()),
            ..Default::default()
        },
    );
    let rcfg = ReplicationConfig {
        leader: addr.to_string(),
        poll_interval: Duration::from_millis(20),
        ..Default::default()
    };
    let followed = bootstrap_follower(&fsvc, &rcfg)?;
    println!("follower bootstrapped from {addr}: models [{}]", followed.join(", "));

    let fsvc_srv = Arc::clone(&fsvc);
    let (ftx, frx) = std::sync::mpsc::channel();
    let fserver = std::thread::spawn(move || {
        serve(fsvc_srv, "127.0.0.1:0", 4, move |a| {
            ftx.send(a).unwrap();
        })
    });
    let faddr = frx.recv()?;
    let mut fclient = Client::connect(faddr)?;
    loop {
        let fstats = fclient.stats(DEFAULT_MODEL)?;
        let lag = fstats
            .get("replication_lag_epochs")
            .and_then(dare::util::json::Value::as_u64)
            .unwrap_or(u64::MAX);
        if lag == 0 {
            println!(
                "follower caught up at {faddr}: role {}, wal epoch {}",
                fstats.get("role").and_then(dare::util::json::Value::as_str).unwrap_or("?"),
                fstats.get("wal_epoch").and_then(dare::util::json::Value::as_u64).unwrap_or(0),
            );
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    println!(
        "leader-minted certificate verifies on the follower: {}",
        fclient.verify_cert(&cert)?
    );
    match fclient.delete(DEFAULT_MODEL, &[200]) {
        Err(ApiError::ReadOnly { leader }) => {
            println!("follower refuses deletion (read_only): redirect to leader at {leader}");
        }
        other => anyhow::bail!("expected a read_only refusal from the follower, got {other:?}"),
    }
    fclient.shutdown()?;
    fserver.join().unwrap()?;
    let _ = std::fs::remove_dir_all(&follower_root);

    client.shutdown()?;
    server.join().unwrap()?;
    let _ = std::fs::remove_dir_all(&wal_root);
    println!("service stopped cleanly");
    Ok(())
}
