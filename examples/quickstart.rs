//! Quickstart: train a DaRE forest, unlearn some instances, verify the
//! model stays accurate, save/load a snapshot — then serve the model over
//! the typed, versioned wire API and file a deletion through the typed
//! client (`Client::delete` / `Client::predict`, DESIGN.md §10).
//!
//!     cargo run --release --offline --example quickstart

use dare::coordinator::{serve, Client, ServiceConfig, UnlearningService, DEFAULT_MODEL};
use dare::data::registry::find;
use dare::data::split::train_test;
use dare::forest::{serialize, DareForest, Params};
use dare::util::timer::time;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. A corpus dataset (1/200th of the paper's Surgical; see DESIGN.md §2).
    let info = find("surgical").expect("corpus dataset");
    let data = info.generate(200, 0);
    let (train, test) = train_test(&data, 0.8, 0);
    let (_, test_ys, _) = test.to_row_major();
    println!(
        "surgical @ 1/200 scale: {} train / {} test instances, {} attributes",
        train.n_total(),
        test.n_total(),
        train.n_features()
    );

    // 2. Train G-DaRE with the paper's tuned hyperparameters (Table 6).
    let params = Params::gdare(&info.gini).with_threads(4);
    let (mut forest, secs) = time(|| DareForest::fit(train, &params, 42));
    let probs = forest.predict_proba_dataset(&test);
    let acc_before = info.metric.score(&probs, &test_ys);
    println!("trained {} trees in {secs:.2}s; test acc = {acc_before:.4}", params.n_trees);

    // 3. Exactly unlearn 50 training instances.
    let victims: Vec<u32> = forest.live_ids().into_iter().take(50).collect();
    let (_, del_secs) = time(|| {
        for &id in &victims {
            forest.delete(id).expect("live instance");
        }
    });
    println!(
        "unlearned {} instances in {del_secs:.3}s ({:.1}ms each)",
        victims.len(),
        1000.0 * del_secs / victims.len() as f64
    );

    // 4. The model is exactly what retraining on the reduced data gives.
    let probs = forest.predict_proba_dataset(&test);
    let acc_after = info.metric.score(&probs, &test_ys);
    println!("test acc after unlearning = {acc_after:.4} (Δ {:+.4})", acc_after - acc_before);

    // 5. Snapshot round-trip.
    let path = std::env::temp_dir().join("dare_quickstart.json");
    serialize::save(&forest, &path)?;
    let loaded = serialize::load(&path)?;
    assert_eq!(loaded.n_alive(), forest.n_alive());
    println!("snapshot saved + reloaded: {} live instances", loaded.n_alive());
    std::fs::remove_file(&path).ok();

    // 6. Serve it: the reloaded model becomes the registry's "default"
    //    model behind the versioned wire API (v0 requests still work; the
    //    typed client speaks v1 and returns typed outcomes/errors).
    let probe = loaded.data().row(loaded.live_ids()[0]);
    let next_victims: Vec<u32> = loaded.live_ids().into_iter().skip(50).take(5).collect();
    let svc = UnlearningService::new(loaded, ServiceConfig::default());
    let svc_srv = Arc::clone(&svc);
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(svc_srv, "127.0.0.1:0", 2, move |a| {
            tx.send(a).unwrap();
        })
    });
    let addr = rx.recv()?;
    let mut client = Client::connect(addr)?;
    let pred = client.predict(DEFAULT_MODEL, &[probe])?;
    println!(
        "served prediction p(+) = {:.4} (engine {})",
        pred.probs[0], pred.engine
    );
    // a GDPR request over the wire: typed outcome, no JSON assembly
    let out = client.delete(DEFAULT_MODEL, &next_victims)?;
    println!(
        "wire deletion: {} removed, retrain cost {} instances (batch of {})",
        out.deleted, out.retrain_cost, out.batch_size
    );
    client.shutdown()?;
    server.join().unwrap()?;
    println!("service stopped cleanly");
    Ok(())
}
