//! Time-budgeted, deadline-aware serving (DESIGN.md §15): several tenants
//! behind one coordinator with a `Scheduler` attached — ticket queues,
//! learned per-(tenant, op, batch-bucket) cost models, EDF for deadlined
//! traffic, deficit-round-robin fairness for the rest, admission control
//! past a queue-depth bound, and background compaction *bidding* for
//! slack instead of stealing foreground time.
//!
//!     cargo run --release --offline --example scheduled_serving
//!
//! The example drives `submit` / `run_for` directly so every scheduling
//! decision is visible; behind `serve()` the attached scheduler does the
//! same thing with a runner thread (`dare serve --budget-ms 10`).

use dare::coordinator::api::ApiError;
use dare::coordinator::{
    Scheduler, SchedulerConfig, ServiceConfig, Submitted, UnlearningService,
};
use dare::data::synth::{generate, SynthSpec};
use dare::forest::{DareForest, Params};
use dare::util::json::{parse, Value};
use std::time::Duration;

fn tenant_forest(n: usize, seed: u64) -> DareForest {
    let data = generate(
        &SynthSpec {
            n,
            informative: 4,
            redundant: 1,
            noise: 2,
            flip: 0.05,
            ..Default::default()
        },
        seed,
    );
    DareForest::fit(
        data,
        &Params {
            n_trees: 6,
            max_depth: 6,
            k: 8,
            ..Default::default()
        },
        seed ^ 0xDA2E,
    )
}

fn predict_req(tenant: &str, deadline_ms: Option<u64>) -> Value {
    let deadline = deadline_ms
        .map(|ms| format!(r#","deadline_ms":{ms}"#))
        .unwrap_or_default();
    parse(&format!(
        r#"{{"v":1,"model":"{tenant}","op":"predict","rows":[[0.2,-0.4,1.0,0.0,0.6,-1.2,0.8]]{deadline}}}"#
    ))
    .unwrap()
}

fn main() -> anyhow::Result<()> {
    println!("training two tenant models...");
    let svc = UnlearningService::with_models(
        vec![
            ("gold".to_string(), tenant_forest(900, 7)),
            ("free".to_string(), tenant_forest(900, 8)),
        ],
        ServiceConfig {
            // Compaction belongs to the scheduler's slack in this example.
            compact_interval: Duration::from_secs(3600),
            ..Default::default()
        },
    );

    // Gold pays for 3x the service share; 10 ms budget cycles; refuse a
    // tenant past 64 queued tickets.
    let mut cfg = SchedulerConfig::default();
    cfg.budget = Duration::from_millis(10);
    cfg.queue_depth = 64;
    cfg.weights =
        SchedulerConfig::parse_weights("gold=3,free=1").map_err(|e| anyhow::anyhow!(e))?;
    let sched = Scheduler::attach(&svc, cfg);

    // --- a synchronized burst: both tenants pile on at once ----------------
    let mut replies = Vec::new();
    for _ in 0..40 {
        for tenant in ["gold", "free"] {
            match sched.submit(&predict_req(tenant, None))? {
                Submitted::Queued(rx) => replies.push(rx),
                Submitted::Immediate(_) => unreachable!("predict always queues"),
            }
        }
    }
    // One deadlined straggler: EDF pulls it (and its tenant's queue) ahead
    // of every no-deadline ticket, without reordering within the tenant.
    let Submitted::Queued(urgent) = sched.submit(&predict_req("free", Some(15)))? else {
        unreachable!()
    };

    let mut cycles = 0;
    while sched.queued_total() > 0 {
        let r = sched.run_for(Duration::from_millis(10));
        cycles += 1;
        if cycles <= 3 {
            println!(
                "cycle {cycles}: executed {} tickets in {:.3} ms (budget 10 ms, {} left)",
                r.executed,
                r.spent_s * 1e3,
                r.remaining
            );
        }
    }
    println!("burst drained in {cycles} budget cycles");
    let probs = urgent.recv()?;
    println!(
        "deadlined request served ok={}",
        probs.get("ok").and_then(Value::as_bool).unwrap_or(false)
    );
    for rx in replies {
        assert_eq!(rx.recv()?.get("ok").and_then(Value::as_bool), Some(true));
    }
    for tenant in ["gold", "free"] {
        let ts = sched.tenant_stats(tenant);
        println!(
            "  {:<5} weight={} executed={} mean wait={:.3} ms",
            tenant,
            ts.get("weight").and_then(Value::as_f64).unwrap_or(1.0),
            ts.get("executed").and_then(Value::as_u64).unwrap_or(0),
            ts.get("waited_s").and_then(Value::as_f64).unwrap_or(0.0) * 1e3
                / ts.get("executed").and_then(Value::as_u64).unwrap_or(1).max(1) as f64
        );
    }

    // --- admission control: the 65th queued ticket is refused ---------------
    let mut queued = Vec::new();
    let refusal = loop {
        match sched.submit(&predict_req("free", None)) {
            Ok(Submitted::Queued(rx)) => queued.push(rx),
            Ok(Submitted::Immediate(_)) => unreachable!(),
            Err(e) => break e,
        }
    };
    let retry_after_ms = match refusal {
        ApiError::Overloaded { retry_after_ms } => retry_after_ms,
        other => anyhow::bail!("expected Overloaded, got {other:?}"),
    };
    println!(
        "admission control: refused after {} queued tickets, retry_after_ms={retry_after_ms}",
        queued.len()
    );
    while sched.queued_total() > 0 {
        sched.run_for(Duration::from_millis(10));
    }
    for rx in queued {
        rx.recv()?;
    }

    // --- background compaction bids for slack --------------------------------
    let delete =
        parse(r#"{"v":1,"model":"gold","op":"delete","ids":[3,4,5,6,7,8,9,10]}"#).unwrap();
    if let Submitted::Queued(rx) = sched.submit(&delete)? {
        while sched.queued_total() > 0 {
            sched.run_for(Duration::from_millis(10));
        }
        rx.recv()?;
    }
    assert!(sched.bid_compact("gold", 1_000));
    let r = sched.run_for(Duration::from_millis(10));
    let model = svc.registry().get("gold")?;
    println!(
        "slack cycle ran {} background ticket(s); compact_ticks={}, pending retrains={}",
        r.executed_bg,
        model.telemetry().counter("compact_ticks"),
        model.sharded().pending_retrains()
    );

    println!("scheduled serving example done");
    Ok(())
}
