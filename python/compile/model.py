"""L2: the JAX compute graphs AOT-lowered for the Rust runtime.

Two graphs, both jitted and exported as HLO text by `aot.py`:

1. `batch_split_scores` — wraps the L1 Pallas kernel
   (`kernels.split_scores`) so split-criterion scoring over cached
   (attribute x threshold) statistics runs as one fused XLA computation.

2. `forest_predict` — batched forest inference over a *tensorized* forest:
   each tree is flattened (BFS order) into fixed-size node arrays
   (attribute, threshold, left/right child, leaf value); traversal is a
   gather-based loop unrolled to the padded node-array depth bound. Leaves
   self-loop, so once a path reaches a leaf further steps are no-ops. Padded
   trees are single leaves with value 0 and the caller divides by the real
   tree count — the sum over padded trees is exact.

Python never runs at request time: Rust loads the lowered HLO through PJRT
(`rust/src/runtime/`).
"""

import jax
import jax.numpy as jnp

from compile.kernels.split_scores import split_scores


def batch_split_scores_gini(n, n_pos, n_left, n_left_pos):
    """Gini scores for a flat, BLOCK-padded candidate batch (L1 kernel)."""
    return (split_scores(n, n_pos, n_left, n_left_pos, criterion="gini"),)


def batch_split_scores_entropy(n, n_pos, n_left, n_left_pos):
    """Entropy scores for a flat, BLOCK-padded candidate batch (L1 kernel)."""
    return (split_scores(n, n_pos, n_left, n_left_pos, criterion="entropy"),)


def forest_predict(x, attr, thresh, left, right, value, depth: int):
    """Batched positive-class scores, summed over trees.

    x:      (B, P) float32
    attr:   (T, M) int32 — split attribute (leaves: 0)
    thresh: (T, M) float32 — threshold (leaves: 0)
    left:   (T, M) int32 — left-child node index (leaves: self)
    right:  (T, M) int32 — right-child node index (leaves: self)
    value:  (T, M) float32 — leaf value (internal: anything, unread)
    depth:  static unroll bound (max tree depth)

    Returns (B,) float32 = sum over trees of leaf values; the caller divides
    by the live tree count (padded trees contribute 0).
    """
    B = x.shape[0]
    T = attr.shape[0]

    # idx[t, b] — current node of example b in tree t.
    idx = jnp.zeros((T, B), dtype=jnp.int32)

    def step(_, idx):
        a = jnp.take_along_axis(attr, idx, axis=1)  # (T, B)
        v = jnp.take_along_axis(thresh, idx, axis=1)  # (T, B)
        # feature values per (tree, example): x[b, a[t,b]] as a 2-D gather —
        # NOT a (T, B, P) broadcast, which would materialize T copies of the
        # feature batch per step (§Perf: 49 ms → ~5 ms per 256-row batch).
        xa = jnp.take_along_axis(x, a.T, axis=1).T  # (T, B)
        go_left = xa <= v
        l = jnp.take_along_axis(left, idx, axis=1)
        r = jnp.take_along_axis(right, idx, axis=1)
        return jnp.where(go_left, l, r)

    idx = jax.lax.fori_loop(0, depth, step, idx)
    leaf_vals = jnp.take_along_axis(value, idx, axis=1)  # (T, B)
    return (jnp.sum(leaf_vals, axis=0),)


def make_forest_predict(depth: int):
    """Bind the static unroll depth for lowering."""

    def fn(x, attr, thresh, left, right, value):
        return forest_predict(x, attr, thresh, left, right, value, depth)

    return fn
