"""Pure-jnp oracle for the split-criterion kernels.

This is the correctness reference (paper Eq. 2 / Eq. 3) that the Pallas
kernel in `split_scores.py` is validated against at build time, and that the
Rust scorer (`rust/src/forest/criterion.rs`, `rust/src/runtime/scorer.rs`)
matches semantically.

All inputs are float32 count arrays of one shape:
  n          -- |D| at the node (broadcast per candidate)
  n_pos      -- |D_{.,1}| at the node
  n_left     -- |D_l| for the candidate threshold
  n_left_pos -- |D_{l,1}| for the candidate threshold
Outputs are float32 scores; lower is better. Empty branches contribute 0,
matching the Rust implementation.
"""

import jax.numpy as jnp


def _safe_div(a, b):
    """a/b with 0 where b == 0."""
    return jnp.where(b > 0, a / jnp.maximum(b, 1.0), 0.0)


def gini_ref(n, n_pos, n_left, n_left_pos):
    """Weighted Gini index of the binary split (paper Eq. 2)."""
    n = n.astype(jnp.float32)
    n_pos = n_pos.astype(jnp.float32)
    n_left = n_left.astype(jnp.float32)
    n_left_pos = n_left_pos.astype(jnp.float32)
    n_right = n - n_left
    n_right_pos = n_pos - n_left_pos

    def side(nb, nb_pos):
        p1 = _safe_div(nb_pos, nb)
        imp = 1.0 - p1 * p1 - (1.0 - p1) * (1.0 - p1)
        w = _safe_div(nb, n)
        return jnp.where(nb > 0, w * imp, 0.0)

    return side(n_left, n_left_pos) + side(n_right, n_right_pos)


def entropy_ref(n, n_pos, n_left, n_left_pos):
    """Weighted entropy of the binary split (paper Eq. 3)."""
    n = n.astype(jnp.float32)
    n_pos = n_pos.astype(jnp.float32)
    n_left = n_left.astype(jnp.float32)
    n_left_pos = n_left_pos.astype(jnp.float32)
    n_right = n - n_left
    n_right_pos = n_pos - n_left_pos

    def h(p):
        # -p log2 p - (1-p) log2 (1-p), with 0 at the endpoints
        def term(q):
            return jnp.where(
                (q > 0.0) & (q < 1.0), -q * jnp.log2(jnp.clip(q, 1e-30, 1.0)), 0.0
            )

        return term(p) + term(1.0 - p)

    def side(nb, nb_pos):
        p1 = _safe_div(nb_pos, nb)
        w = _safe_div(nb, n)
        return jnp.where(nb > 0, w * h(p1), 0.0)

    return side(n_left, n_left_pos) + side(n_right, n_right_pos)


def forest_predict_ref(x, attr, thresh, left, right, value, n_real_trees):
    """Reference batched forest inference via plain python traversal.

    x:      (B, P) float32 features
    attr:   (T, M) int32   split attribute per node (leaves: 0)
    thresh: (T, M) float32 split threshold (leaves: 0)
    left:   (T, M) int32   left-child index (leaves: self-loop)
    right:  (T, M) int32   right-child index (leaves: self-loop)
    value:  (T, M) float32 leaf value (internal nodes: 0, unused)
    n_real_trees: padded trees are all-leaf value 0; the mean divides by the
        real count.
    Returns (B,) positive-class probabilities.

    This python-loop version exists only as a test oracle; the L2 graph in
    `model.py` is the vectorized/jitted implementation.
    """
    import numpy as np

    x = np.asarray(x)
    attr = np.asarray(attr)
    thresh = np.asarray(thresh)
    left = np.asarray(left)
    right = np.asarray(right)
    value = np.asarray(value)
    B = x.shape[0]
    T, _ = attr.shape
    out = np.zeros(B, dtype=np.float32)
    for b in range(B):
        s = 0.0
        for t in range(T):
            idx = 0
            # at most M steps; leaves self-loop so extra steps are no-ops
            for _ in range(attr.shape[1]):
                nxt = (
                    left[t, idx]
                    if x[b, attr[t, idx]] <= thresh[t, idx]
                    else right[t, idx]
                )
                if nxt == idx:
                    break
                idx = nxt
            s += value[t, idx]
        out[b] = s / float(n_real_trees)
    return out
