"""L1 Pallas kernel: batched split-criterion scoring.

The one dense hot-spot of DaRE training/deletion is scoring the cached
(attribute x threshold) statistic tables with Gini (Eq. 2) or entropy
(Eq. 3). On the paper's CPU implementation this is a scalar loop over
p-tilde * k candidates per node; here it is re-thought for the TPU model
(DESIGN.md section Hardware-Adaptation):

  - the candidate table is laid out as a flat float32 vector of counts
    (n, n_pos, n_left, n_left_pos), padded to a block multiple;
  - the Pallas grid tiles the table into VMEM-resident blocks of
    BLOCK candidates; each block is scored fully vectorized on the VPU
    (no MXU needed: the kernel is elementwise);
  - `interpret=True` is mandatory for CPU-PJRT execution (real TPU lowering
    emits a Mosaic custom-call the CPU plugin cannot run).

VMEM footprint per block: 4 inputs + 1 output = 5 * BLOCK * 4 bytes
(= 40 KiB at BLOCK=2048), far under the ~16 MiB VMEM budget, leaving room
for double-buffering the HBM->VMEM pipeline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Candidates per grid block (VPU lane-friendly multiple of 128).
BLOCK = 2048


def _score_block(n, n_pos, n_left, n_left_pos, criterion):
    """Vectorized criterion over one block of candidate counts."""
    n_right = n - n_left
    n_right_pos = n_pos - n_left_pos

    def safe_div(a, b):
        return jnp.where(b > 0, a / jnp.maximum(b, 1.0), 0.0)

    if criterion == "gini":

        def side(nb, nb_pos):
            p1 = safe_div(nb_pos, nb)
            imp = 1.0 - p1 * p1 - (1.0 - p1) * (1.0 - p1)
            return jnp.where(nb > 0, safe_div(nb, n) * imp, 0.0)

    elif criterion == "entropy":

        def h(p):
            def term(q):
                return jnp.where(
                    (q > 0.0) & (q < 1.0),
                    -q * jnp.log2(jnp.clip(q, 1e-30, 1.0)),
                    0.0,
                )

            return term(p) + term(1.0 - p)

        def side(nb, nb_pos):
            p1 = safe_div(nb_pos, nb)
            return jnp.where(nb > 0, safe_div(nb, n) * h(p1), 0.0)

    else:  # pragma: no cover - guarded by callers
        raise ValueError(f"unknown criterion {criterion!r}")

    return side(n_left, n_left_pos) + side(n_right, n_right_pos)


def _kernel(n_ref, np_ref, nl_ref, nlp_ref, out_ref, *, criterion):
    """Pallas kernel body: score one VMEM-resident block."""
    out_ref[...] = _score_block(
        n_ref[...], np_ref[...], nl_ref[...], nlp_ref[...], criterion
    )


@functools.partial(jax.jit, static_argnames=("criterion",))
def split_scores(n, n_pos, n_left, n_left_pos, criterion="gini"):
    """Score a flat batch of split candidates with the Pallas kernel.

    All four inputs are float32 arrays of the same 1-D shape whose length
    must be a multiple of BLOCK (callers pad; padded entries are scored but
    ignored downstream). Returns float32 scores of the same shape.
    """
    (total,) = n.shape
    assert total % BLOCK == 0, f"pad candidate count to a multiple of {BLOCK}"
    grid = (total // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_kernel, criterion=criterion),
        out_shape=jax.ShapeDtypeStruct((total,), jnp.float32),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(
        n.astype(jnp.float32),
        n_pos.astype(jnp.float32),
        n_left.astype(jnp.float32),
        n_left_pos.astype(jnp.float32),
    )


def pad_to_block(arr, fill=0.0):
    """Pad a 1-D array up to the next BLOCK multiple."""
    import numpy as np

    arr = np.asarray(arr, dtype=np.float32)
    rem = (-len(arr)) % BLOCK
    if rem == 0 and len(arr) > 0:
        return arr
    return np.concatenate([arr, np.full(max(rem, BLOCK if len(arr) == 0 else rem), fill, dtype=np.float32)])
