"""AOT lowering: JAX -> stablehlo -> XlaComputation -> HLO *text*.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts written (plus artifacts/manifest.json describing shapes):
  split_scores_gini.hlo.txt     — L1 Pallas kernel, Gini, flat batch
  split_scores_entropy.hlo.txt  — L1 Pallas kernel, entropy, flat batch
  forest_predict.hlo.txt        — L2 tensorized-forest inference graph

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.split_scores import BLOCK

# Canonical artifact shapes — the Rust runtime pads to these (manifest.json
# records them so Rust never hard-codes).
SCORE_BATCH = 4 * BLOCK  # 8192 candidates per scorer call
PRED_BATCH = 256  # examples per predictor call
PRED_FEATURES = 64  # feature slots (pad columns with zeros)
# Two predict variants: XLA-CPU gather cost scales with the padded tree
# count, so small forests should not pay for 128 slots (§Perf).
PRED_TREES = 128  # large variant (paper T <= 250; most entries <= 100)
PRED_TREES_SMALL = 32  # small variant for <= 32-tree forests
PRED_NODES = 4096  # node slots per tree
PRED_DEPTH = 24  # traversal unroll bound (>= max_depth + random layers)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_scores(criterion: str) -> str:
    fn = (
        model.batch_split_scores_gini
        if criterion == "gini"
        else model.batch_split_scores_entropy
    )
    spec = jax.ShapeDtypeStruct((SCORE_BATCH,), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec, spec, spec)
    return to_hlo_text(lowered)


def lower_predict(trees: int = PRED_TREES) -> str:
    fn = model.make_forest_predict(PRED_DEPTH)
    x = jax.ShapeDtypeStruct((PRED_BATCH, PRED_FEATURES), jnp.float32)
    ti = jax.ShapeDtypeStruct((trees, PRED_NODES), jnp.int32)
    tf = jax.ShapeDtypeStruct((trees, PRED_NODES), jnp.float32)
    lowered = jax.jit(fn).lower(x, ti, tf, ti, ti, tf)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = {}

    for crit in ("gini", "entropy"):
        name = f"split_scores_{crit}.hlo.txt"
        text = lower_scores(crit)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        artifacts[f"split_scores_{crit}"] = {
            "file": name,
            "batch": SCORE_BATCH,
            "block": BLOCK,
            "inputs": ["n", "n_pos", "n_left", "n_left_pos"],
        }
        print(f"wrote {name} ({len(text)} chars)")

    for key, trees in (("forest_predict", PRED_TREES), ("forest_predict_small", PRED_TREES_SMALL)):
        name = f"{key}.hlo.txt"
        text = lower_predict(trees)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        artifacts[key] = {
            "file": name,
            "batch": PRED_BATCH,
            "features": PRED_FEATURES,
            "trees": trees,
            "nodes": PRED_NODES,
            "depth": PRED_DEPTH,
        }
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"format": "dare-artifacts-v1", "artifacts": artifacts}, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
