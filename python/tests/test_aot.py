"""AOT path: lowering produces parseable HLO text and a complete manifest."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


def test_lower_scores_produces_hlo_text():
    for crit in ("gini", "entropy"):
        text = aot.lower_scores(crit)
        assert "ENTRY" in text, "HLO text must contain an entry computation"
        assert "f32[%d]" % aot.SCORE_BATCH in text
        # interpret-mode pallas lowers to plain HLO: no Mosaic custom-calls
        assert "tpu_custom_call" not in text.lower()


def test_lower_predict_produces_hlo_text():
    text = aot.lower_predict()
    assert "ENTRY" in text
    assert "f32[%d,%d]" % (aot.PRED_BATCH, aot.PRED_FEATURES) in text
    assert "tpu_custom_call" not in text.lower()


def test_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "dare-artifacts-v1"
    arts = manifest["artifacts"]
    for key in ("split_scores_gini", "split_scores_entropy", "forest_predict"):
        assert key in arts
        assert (out / arts[key]["file"]).exists()
        assert (out / arts[key]["file"]).stat().st_size > 100
    assert arts["forest_predict"]["depth"] >= 20


@pytest.mark.parametrize("crit", ["gini", "entropy"])
def test_lowered_scores_execute_in_jax(crit):
    """Executing the jitted function (the thing we lower) works end-to-end."""
    import jax.numpy as jnp
    import numpy as np

    from compile import model

    fn = (
        model.batch_split_scores_gini
        if crit == "gini"
        else model.batch_split_scores_entropy
    )
    b = aot.SCORE_BATCH
    n = jnp.full((b,), 10.0, dtype=jnp.float32)
    npos = jnp.full((b,), 4.0, dtype=jnp.float32)
    nl = jnp.full((b,), 6.0, dtype=jnp.float32)
    nlp = jnp.full((b,), 1.0, dtype=jnp.float32)
    (out,) = fn(n, npos, nl, nlp)
    assert out.shape == (b,)
    assert bool(jnp.all(jnp.isfinite(out)))
    if crit == "gini":
        expect = 0.6 * (10.0 / 36.0) + 0.4 * (6.0 / 16.0)
        np.testing.assert_allclose(np.asarray(out)[0], expect, atol=1e-6)
