"""L2 graph correctness: vectorized forest_predict vs the python-loop oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import forest_predict_ref
from compile.model import forest_predict


def random_forest_arrays(rng, n_trees, max_nodes, n_features, depth):
    """Generate random, structurally valid tensorized trees.

    Builds each tree top-down; node 0 is the root. Internal nodes get two
    children while the node budget lasts; leaves self-loop with a random
    value in [0, 1].
    """
    T, M = n_trees, max_nodes
    attr = np.zeros((T, M), dtype=np.int32)
    thresh = np.zeros((T, M), dtype=np.float32)
    left = np.tile(np.arange(M, dtype=np.int32), (T, 1))
    right = left.copy()
    value = rng.random((T, M)).astype(np.float32)

    for t in range(T):
        next_free = 1
        frontier = [(0, 0)]  # (node, depth)
        while frontier:
            node, d = frontier.pop()
            if d >= depth or next_free + 1 >= M or rng.random() < 0.3:
                continue  # leaf: self-loop already set
            attr[t, node] = rng.integers(0, n_features)
            thresh[t, node] = rng.normal()
            left[t, node] = next_free
            right[t, node] = next_free + 1
            frontier.append((next_free, d + 1))
            frontier.append((next_free + 1, d + 1))
            next_free += 2
    return attr, thresh, left, right, value


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_predict_matches_reference(seed):
    rng = np.random.default_rng(seed)
    T, M, P, D, B = 4, 64, 6, 5, 16
    attr, thresh, left, right, value = random_forest_arrays(rng, T, M, P, D)
    x = rng.normal(size=(B, P)).astype(np.float32)
    (got,) = forest_predict(
        jnp.array(x), jnp.array(attr), jnp.array(thresh),
        jnp.array(left), jnp.array(right), jnp.array(value), depth=M,
    )
    got = np.asarray(got) / T
    want = forest_predict_ref(x, attr, thresh, left, right, value, T)
    np.testing.assert_allclose(got, want, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_predict_matches_reference_hypothesis(seed):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 6))
    P = int(rng.integers(1, 8))
    D = int(rng.integers(1, 6))
    B = int(rng.integers(1, 24))
    M = 64
    attr, thresh, left, right, value = random_forest_arrays(rng, T, M, P, D)
    x = rng.normal(size=(B, P)).astype(np.float32)
    (got,) = forest_predict(
        jnp.array(x), jnp.array(attr), jnp.array(thresh),
        jnp.array(left), jnp.array(right), jnp.array(value), depth=D + 2,
    )
    got = np.asarray(got) / T
    want = forest_predict_ref(x, attr, thresh, left, right, value, T)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_padded_trees_contribute_zero():
    """Padding trees as value-0 single leaves must not change the sum."""
    rng = np.random.default_rng(7)
    T, M, P, D, B = 3, 32, 4, 4, 8
    attr, thresh, left, right, value = random_forest_arrays(rng, T, M, P, D)
    x = rng.normal(size=(B, P)).astype(np.float32)

    def pad(arrs, extra):
        attr, thresh, left, right, value = arrs
        T0, M0 = attr.shape
        za = np.zeros((extra, M0), dtype=attr.dtype)
        zf = np.zeros((extra, M0), dtype=np.float32)
        sl = np.tile(np.arange(M0, dtype=np.int32), (extra, 1))
        return (
            np.vstack([attr, za]),
            np.vstack([thresh, zf]),
            np.vstack([left, sl]),
            np.vstack([right, sl]),
            np.vstack([value, zf]),
        )

    (base,) = forest_predict(
        jnp.array(x), jnp.array(attr), jnp.array(thresh),
        jnp.array(left), jnp.array(right), jnp.array(value), depth=D + 1,
    )
    pa, pt, pl_, pr, pv = pad((attr, thresh, left, right, value), 5)
    (padded,) = forest_predict(
        jnp.array(x), jnp.array(pa), jnp.array(pt),
        jnp.array(pl_), jnp.array(pr), jnp.array(pv), depth=D + 1,
    )
    np.testing.assert_allclose(np.asarray(base), np.asarray(padded), atol=1e-6)


def test_single_leaf_forest():
    """A forest of bare leaves predicts the leaf values regardless of x."""
    T, M, P, B = 2, 8, 3, 5
    attr = np.zeros((T, M), dtype=np.int32)
    thresh = np.zeros((T, M), dtype=np.float32)
    idx = np.tile(np.arange(M, dtype=np.int32), (T, 1))
    value = np.zeros((T, M), dtype=np.float32)
    value[0, 0] = 1.0
    value[1, 0] = 0.5
    x = np.random.default_rng(0).normal(size=(B, P)).astype(np.float32)
    (got,) = forest_predict(
        jnp.array(x), jnp.array(attr), jnp.array(thresh),
        jnp.array(idx), jnp.array(idx), jnp.array(value), depth=4,
    )
    np.testing.assert_allclose(np.asarray(got), np.full(B, 1.5), atol=1e-6)
