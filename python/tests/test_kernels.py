"""L1 kernel correctness: Pallas split_scores vs the pure-jnp oracle.

The hypothesis sweep drives random count tables (including the tie-heavy and
empty-branch edge cases) through both implementations and requires exact
float32 agreement patterns (allclose at 1e-6).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import entropy_ref, gini_ref
from compile.kernels.split_scores import BLOCK, pad_to_block, split_scores


def random_counts(rng, total):
    """Valid count tables: n >= n_left, n_pos >= n_left_pos, etc."""
    n = rng.integers(1, 1000, size=total).astype(np.float32)
    n_pos = (rng.random(total) * n).astype(np.int64).astype(np.float32)
    n_left = (rng.random(total) * n).astype(np.int64).astype(np.float32)
    # n_left_pos <= min(n_left, n_pos) and n_right_pos >= 0:
    lo = np.maximum(0, n_pos - (n - n_left))
    hi = np.minimum(n_left, n_pos)
    n_left_pos = (lo + rng.random(total) * (hi - lo)).astype(np.int64).astype(np.float32)
    return n, n_pos, n_left, n_left_pos


@pytest.mark.parametrize("criterion", ["gini", "entropy"])
def test_kernel_matches_ref_basic(criterion):
    rng = np.random.default_rng(0)
    n, n_pos, n_left, n_left_pos = random_counts(rng, BLOCK)
    got = split_scores(
        jnp.array(n), jnp.array(n_pos), jnp.array(n_left), jnp.array(n_left_pos),
        criterion=criterion,
    )
    ref_fn = gini_ref if criterion == "gini" else entropy_ref
    want = ref_fn(jnp.array(n), jnp.array(n_pos), jnp.array(n_left), jnp.array(n_left_pos))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("criterion", ["gini", "entropy"])
@pytest.mark.parametrize("blocks", [1, 2, 4])
def test_kernel_grid_tiling(criterion, blocks):
    """Multi-block grids must score identically to one concatenated ref call."""
    rng = np.random.default_rng(blocks)
    n, n_pos, n_left, n_left_pos = random_counts(rng, blocks * BLOCK)
    got = split_scores(
        jnp.array(n), jnp.array(n_pos), jnp.array(n_left), jnp.array(n_left_pos),
        criterion=criterion,
    )
    ref_fn = gini_ref if criterion == "gini" else entropy_ref
    want = ref_fn(jnp.array(n), jnp.array(n_pos), jnp.array(n_left), jnp.array(n_left_pos))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    criterion=st.sampled_from(["gini", "entropy"]),
)
def test_kernel_matches_ref_hypothesis(seed, criterion):
    rng = np.random.default_rng(seed)
    n, n_pos, n_left, n_left_pos = random_counts(rng, BLOCK)
    got = split_scores(
        jnp.array(n), jnp.array(n_pos), jnp.array(n_left), jnp.array(n_left_pos),
        criterion=criterion,
    )
    ref_fn = gini_ref if criterion == "gini" else entropy_ref
    want = ref_fn(jnp.array(n), jnp.array(n_pos), jnp.array(n_left), jnp.array(n_left_pos))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("criterion", ["gini", "entropy"])
def test_edge_cases(criterion):
    """Pure splits score 0; empty branches don't NaN; useless splits max out."""
    n = np.full(BLOCK, 8.0, dtype=np.float32)
    n_pos = np.full(BLOCK, 4.0, dtype=np.float32)
    # candidate 0: perfect split (left = all pos)
    n_left = np.full(BLOCK, 4.0, dtype=np.float32)
    n_left_pos = np.zeros(BLOCK, dtype=np.float32)
    n_left_pos[0] = 4.0
    # candidate 1: empty left branch
    n_left[1] = 0.0
    n_left_pos[1] = 0.0
    # candidate 2: useless split (both sides 50/50)
    n_left[2] = 4.0
    n_left_pos[2] = 2.0
    got = np.asarray(
        split_scores(
            jnp.array(n), jnp.array(n_pos), jnp.array(n_left), jnp.array(n_left_pos),
            criterion=criterion,
        )
    )
    assert got[0] == pytest.approx(0.0, abs=1e-6), "perfect split"
    assert np.isfinite(got[1]), "empty branch must not NaN"
    expected_max = 0.5 if criterion == "gini" else 1.0
    assert got[2] == pytest.approx(expected_max, abs=1e-6), "useless split"
    assert np.all(np.isfinite(got))


def test_scores_match_rust_reference_values():
    """Pin the exact values the Rust unit tests assert
    (rust/src/forest/criterion.rs) so all three implementations agree."""
    n = pad_to_block([10.0])
    n_pos = pad_to_block([4.0])
    n_left = pad_to_block([6.0])
    n_left_pos = pad_to_block([1.0])
    gini = np.asarray(
        split_scores(jnp.array(n), jnp.array(n_pos), jnp.array(n_left), jnp.array(n_left_pos))
    )[0]
    expect = 0.6 * (10.0 / 36.0) + 0.4 * (6.0 / 16.0)
    assert gini == pytest.approx(expect, abs=1e-6)

    # entropy pin: n=8, pos=2, left=4 with 2 pos -> 0.5
    e = np.asarray(
        split_scores(
            jnp.array(pad_to_block([8.0])),
            jnp.array(pad_to_block([2.0])),
            jnp.array(pad_to_block([4.0])),
            jnp.array(pad_to_block([2.0])),
            criterion="entropy",
        )
    )[0]
    assert e == pytest.approx(0.5, abs=1e-6)


def test_pad_to_block():
    assert len(pad_to_block([1.0, 2.0])) == BLOCK
    assert len(pad_to_block([0.0] * BLOCK)) == BLOCK
    assert len(pad_to_block([0.0] * (BLOCK + 1))) == 2 * BLOCK
    assert len(pad_to_block([])) == BLOCK
